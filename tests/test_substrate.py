"""Substrate units: sharding rules, data pipeline, optimizer, bundles."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import models
from repro.ckpt import bundle_from_params
from repro.configs import get_config
from repro.data import Prefetcher, SyntheticTokens, make_batch
from repro.dist.sharding import ShardingRules, spec_for
from repro.optim import OptConfig, adamw_update, init_opt_state, lr_at

from conftest import build_app
from repro.core import SymbolRef


# ------------------------------------------------------------------ sharding
class _FakeMesh:
    def __init__(self, shape, names):
        import numpy as _np

        self.devices = _np.empty(shape)
        self.axis_names = names


def test_spec_for_basic_fsdp_tp():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    assert spec_for(("embed", "mlp"), (8192, 22016), mesh) == P("data", "model")
    assert spec_for(("vocab", "embed"), (102400, 8192), mesh) == P(
        "model", "data"
    )


def test_spec_for_divisibility_fallback():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    # 50280 % 16 != 0 -> vocab replicated, embed still sharded
    assert spec_for(("vocab", "embed"), (50280, 1024), mesh) == P(None, "data")
    # batch=1 cannot shard
    assert spec_for(("batch", "seq"), (1, 524288), mesh) == P()


def test_spec_for_no_axis_reuse():
    mesh = _FakeMesh((4, 4), ("data", "model"))
    # both dims want 'model': only the first gets it
    s = spec_for(("heads", "kv_heads"), (16, 16), mesh)
    assert s == P("model")


def test_long_context_rules_shard_cache_seq():
    mesh = _FakeMesh((2, 16, 16), ("pod", "data", "model"))
    rules = ShardingRules.long_context()
    s = spec_for(
        ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
        (26, 1, 524288, 1, 256),
        mesh,
        rules,
    )
    assert s == P(None, None, "data")


# -------------------------------------------------------------------- data
def test_data_deterministic_and_shardable():
    full = make_batch(vocab_size=100, global_batch=8, seq_len=16, step=3)
    again = make_batch(vocab_size=100, global_batch=8, seq_len=16, step=3)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    # shard 1 of 4 == rows 2:4 of the global batch
    shard = make_batch(
        vocab_size=100, global_batch=8, seq_len=16, step=3, shard=1,
        num_shards=4,
    )
    np.testing.assert_array_equal(shard["tokens"], full["tokens"][2:4])
    # labels are next tokens
    assert full["labels"].shape == full["tokens"].shape


def test_data_seek_resume():
    it = SyntheticTokens(vocab_size=50, global_batch=2, seq_len=8)
    b0, b1, b2 = next(it), next(it), next(it)
    it.seek(1)
    np.testing.assert_array_equal(next(it)["tokens"], b1["tokens"])


def test_prefetcher_preserves_order():
    it = SyntheticTokens(vocab_size=50, global_batch=2, seq_len=8)
    direct = [next(it)["tokens"] for _ in range(5)]
    it2 = Prefetcher(SyntheticTokens(vocab_size=50, global_batch=2, seq_len=8))
    fetched = [next(it2)["tokens"] for _ in range(5)]
    for a, b in zip(direct, fetched):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------------- optim
def test_adamw_minimizes_quadratic():
    cfg = OptConfig(peak_lr=0.1, min_lr=0.05, warmup_steps=1,
                    decay_steps=1000, weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clipping_bounds_update():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=0, decay_steps=10, clip_norm=1.0,
                    weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_opt_state(params)
    _, _, metrics = adamw_update(
        params, {"w": jnp.full(4, 1e6)}, state, cfg
    )
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_lr_schedule_warmup_and_decay():
    cfg = OptConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10, decay_steps=100)
    assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(cfg, jnp.int32(100))) == pytest.approx(0.1, rel=1e-3)


# ------------------------------------------------------------------ bundles
def test_bundle_roundtrip_via_linker(linker):
    _, mgr, ex = linker
    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = {
        n: np.asarray(v) for n, v in models.init_params(cfg, 0).items()
    }
    bundle, payload = bundle_from_params("w", "1", params)
    app = build_app("app", models.manifest_refs(cfg), ["w"])
    mgr.update_obj(bundle, payload)
    mgr.update_obj(app)
    mgr.end_mgmt()
    img = ex.load("app", strategy="stable")
    for n, arr in params.items():
        np.testing.assert_array_equal(np.asarray(img[n]), arr, err_msg=n)


def test_fragmented_bundle_resolves_slices(linker):
    """Per-layer refs resolve as SLICEs against a stacked bundle and as
    DIRECTs against a fragmented bundle — same loaded values."""
    _, mgr, ex = linker
    cfg = get_config("olmoe-1b-7b", smoke=True)
    params = {
        n: np.asarray(v) for n, v in models.init_params(cfg, 0).items()
    }
    refs = models.manifest_refs(cfg, fragment=True)
    stacked, p1 = bundle_from_params("stacked", "1", params)
    frag, p2 = bundle_from_params(
        "frag", "1", params, fragment_layers=True, fragment_experts=True
    )
    app_s = build_app("app_s", refs, ["stacked"])
    app_f = build_app("app_f", refs, ["frag"])
    for o, p in [(stacked, p1), (frag, p2), (app_s, b""), (app_f, b"")]:
        mgr.update_obj(o, p)
    mgr.end_mgmt()
    img_s = ex.load("app_s", strategy="stable")
    img_f = ex.load("app_f", strategy="stable")
    from repro.core import RelocType

    types_s = set(img_s.table.rows["type"].tolist())
    types_f = set(img_f.table.rows["type"].tolist())
    assert int(RelocType.SLICE) in types_s
    assert types_f == {int(RelocType.DIRECT)}
    for r in refs:
        np.testing.assert_array_equal(
            np.asarray(img_s[r.name]), np.asarray(img_f[r.name]), err_msg=r.name
        )
