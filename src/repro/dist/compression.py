"""Compression for the distributed tier: gradient quantization + the
byte-level transfer codec used by the arena store.

Two independent halves live here:

* **Int8 gradient compression** — symmetric per-tensor quantization + an
  all-gather-based compressed mean that stands in for ``lax.pmean``. The
  quantization grid is symmetric around zero with 127 positive steps, so
  zero is exact and the roundtrip error is bounded by half a grid step
  (scale/2). ``int8_allreduce_mean`` moves int8 + one f32 scale per shard
  on the wire instead of f32 activations — a 4x traffic cut for ~1% mean
  error on normal-ish gradients. (jax is imported lazily inside these
  functions so the byte codec below stays import-light for ``core/``.)

* **Framed byte codec** — ``encode_bytes``/``decode_bytes`` wrap raw blob
  bytes in a small self-describing frame so store transfers can pick a
  codec per blob and always decode on the other side. Codecs: ``none``
  (identity), ``rle`` (byte run-length, good for zero-padded arena
  images), ``zlib`` (general). Every encoder falls back to a ``none``
  frame when the codec is unavailable or would *grow* the payload, so the
  knob is safe to leave on everywhere.

Frame layout (little-endian)::

    0..4   magic  b"RPBC"
    4      version (1)
    5      codec id (0=none, 1=rle, 2=zlib)
    6..14  raw (decoded) length, uint64
    14..   payload

``decode_bytes`` validates magic, version, codec id and the decoded
length; any mismatch raises :class:`CodecError` — the store treats that
exactly like a content-hash mismatch (quarantine, never admit).
"""

from __future__ import annotations

import struct

_EPS = 1e-30  # all-zero tensors: avoid 0/0; q stays exactly 0


def quantize_int8(x):
    """x -> (int8 codes, f32 scale); codes * scale ~= x to scale/2."""
    import jax.numpy as jnp

    x = jnp.asarray(x)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, _EPS) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    import jax.numpy as jnp

    return q.astype(jnp.float32) * scale


def int8_allreduce_mean(x, axis_name: str):
    """Compressed mean over ``axis_name`` (shard_map/pmap collective axis).

    Each participant quantizes its shard, all-gathers codes + scales, and
    dequantizes locally — wire traffic is ~x.nbytes/4 per hop vs pmean.
    """
    import jax
    import jax.numpy as jnp

    q, s = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)
    ss = jax.lax.all_gather(s, axis_name)
    vals = qs.astype(jnp.float32) * ss.reshape(ss.shape + (1,) * q.ndim)
    return jnp.mean(vals, axis=0)


# ------------------------------------------------------------- byte codec
class CodecError(ValueError):
    """Frame is not a valid codec frame, or the payload does not decode
    to the advertised length (truncated / flipped bytes in transit)."""


_MAGIC = b"RPBC"
_VERSION = 1
_HEADER = struct.Struct("<4sBBQ")  # magic, version, codec id, raw length

_CODEC_IDS = {"none": 0, "rle": 1, "zlib": 2}
_CODEC_NAMES = {v: k for k, v in _CODEC_IDS.items()}


def available_codecs() -> list[str]:
    """Codec names ``encode_bytes`` accepts, in preference order."""
    names = ["none", "rle"]
    try:
        import zlib  # noqa: F401

        names.append("zlib")
    except ImportError:  # pragma: no cover - zlib is stdlib everywhere
        pass
    return names


def _rle_encode(data: bytes) -> bytes:
    # (run_len u8, value u8) pairs; runs longer than 255 split. Vectorised
    # boundary-finding via numpy keeps this usable on multi-MB arenas.
    import numpy as np

    if not data:
        return b""
    arr = np.frombuffer(data, dtype=np.uint8)
    boundaries = np.flatnonzero(np.diff(arr)) + 1
    starts = np.concatenate(([0], boundaries))
    ends = np.concatenate((boundaries, [arr.size]))
    lengths = ends - starts
    values = arr[starts]
    # split runs > 255 into ceil(n/255) chunks
    n_chunks = (lengths + 254) // 255
    out_vals = np.repeat(values, n_chunks)
    out_lens = np.full(out_vals.size, 255, dtype=np.uint64)
    last_idx = np.cumsum(n_chunks) - 1
    rem = lengths - (n_chunks - 1) * 255
    out_lens[last_idx] = rem
    pairs = np.empty((out_vals.size, 2), dtype=np.uint8)
    pairs[:, 0] = out_lens.astype(np.uint8)
    pairs[:, 1] = out_vals
    return pairs.tobytes()


def _rle_decode(payload: bytes) -> bytes:
    import numpy as np

    if not payload:
        return b""
    if len(payload) % 2:
        raise CodecError("rle payload has odd length")
    pairs = np.frombuffer(payload, dtype=np.uint8).reshape(-1, 2)
    if (pairs[:, 0] == 0).any():
        raise CodecError("rle payload contains a zero-length run")
    return np.repeat(pairs[:, 1], pairs[:, 0]).tobytes()


def encode_bytes(data: bytes, codec: str = "zlib", *, level: int = 6) -> bytes:
    """Frame ``data`` with ``codec``; falls back to a ``none`` frame when
    the codec is unavailable or does not shrink the payload."""
    data = bytes(data)
    if codec not in _CODEC_IDS:
        raise CodecError(
            f"unknown codec {codec!r}; available: {', '.join(_CODEC_IDS)}"
        )
    payload = data
    used = "none"
    if codec == "rle":
        encoded = _rle_encode(data)
        if len(encoded) < len(data):
            payload, used = encoded, "rle"
    elif codec == "zlib":
        try:
            import zlib

            encoded = zlib.compress(data, level)
            if len(encoded) < len(data):
                payload, used = encoded, "zlib"
        except ImportError:  # pragma: no cover - stdlib
            pass
    header = _HEADER.pack(_MAGIC, _VERSION, _CODEC_IDS[used], len(data))
    return header + payload


def decode_bytes(frame: bytes) -> bytes:
    """Inverse of :func:`encode_bytes`; raises :class:`CodecError` on any
    malformed, truncated, or wrong-length frame."""
    frame = bytes(frame)
    if len(frame) < _HEADER.size:
        raise CodecError(
            f"frame too short ({len(frame)} bytes < {_HEADER.size} header)"
        )
    magic, version, codec_id, raw_len = _HEADER.unpack_from(frame)
    if magic != _MAGIC:
        raise CodecError(f"bad magic {magic!r} (expected {_MAGIC!r})")
    if version != _VERSION:
        raise CodecError(f"unsupported frame version {version}")
    name = _CODEC_NAMES.get(codec_id)
    if name is None:
        raise CodecError(f"unknown codec id {codec_id}")
    payload = frame[_HEADER.size:]
    if name == "none":
        data = payload
    elif name == "rle":
        data = _rle_decode(payload)
    else:
        import zlib

        try:
            data = zlib.decompress(payload)
        except zlib.error as e:
            raise CodecError(f"zlib payload does not decompress: {e}") from e
    if len(data) != raw_len:
        raise CodecError(
            f"decoded length {len(data)} != advertised {raw_len}"
        )
    return data
