"""Hypothesis property tests over the stable linker's core invariants.

P1: stable (materialized) loading is extensionally EQUAL to dynamic loading
    for any world — the paper's central correctness claim (§4.2: the table
    stores exactly the mapping a traditional dynamic linker produces).
P2: resolution is deterministic (same world -> same relocation mapping).
P3: first-match-wins follows BFS needed-order (interposition semantics).
P4: table save/load roundtrips bit-exactly.
P5: arena layouts never overlap and are page-aligned.
"""

from __future__ import annotations

import numpy as np
import pytest

# hypothesis is an optional dev dependency; environments without it skip the
# property suite instead of failing collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (
    DynamicResolver,
    Executor,
    Manager,
    PAGE_BYTES,
    Registry,
    SymbolRef,
)
from repro.core.relocation import RelocationTable, build_arena_layout

from conftest import build_app, build_bundle

# ---------------------------------------------------------------- strategies
sym_names = st.lists(
    st.text(alphabet="abcdefgh", min_size=1, max_size=6).map(lambda s: "s/" + s),
    min_size=1,
    max_size=12,
    unique=True,
)


@st.composite
def worlds(draw):
    """A random world: n bundles exporting disjoint-or-overlapping symbols,
    one app referencing a subset (some weak)."""
    names = draw(sym_names)
    n_bundles = draw(st.integers(1, 4))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    bundles = []
    for i in range(n_bundles):
        exported = draw(
            st.lists(st.sampled_from(names), unique=True, min_size=0,
                     max_size=len(names))
        )
        tensors = {
            s: rng.standard_normal(draw(st.integers(1, 64))).astype(np.float32)
            for s in exported
        }
        bundles.append((f"lib{i}", tensors))
    exported_anywhere = {s for _, ts in bundles for s in ts}
    refs = []
    for s in names:
        if s in exported_anywhere:
            # shape must match the FIRST provider in search order
            for _, ts in bundles:
                if s in ts:
                    refs.append(SymbolRef(s, ts[s].shape, "float32"))
                    break
        else:
            refs.append(SymbolRef(s, (4,), "float32", weak=True))
    return bundles, refs


def _publish(tmp, bundles, refs):
    reg = Registry(tmp)
    mgr = Manager(reg)
    ex = Executor(reg, mgr)
    objs = [build_bundle(n, ts) for n, ts in bundles]
    app = build_app("app", refs, [n for n, _ in bundles])
    for o, p in objs:
        mgr.update_obj(o, p)
    mgr.update_obj(app)
    mgr.end_mgmt()
    return reg, mgr, ex


@given(worlds())
@settings(max_examples=25, deadline=None)
def test_p1_stable_equals_dynamic(tmp_path_factory_world):
    bundles, refs = tmp_path_factory_world
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        # skip worlds where shapes collide across providers (mismatch error
        # is legitimate; P1 is about resolvable worlds)
        reg, mgr, ex = _publish(tmp, bundles, refs)
        try:
            img_d = ex.load("app", strategy="dynamic")
        except Exception:
            return
        img_s = ex.load("app", strategy="stable")
        assert set(img_d.tensors) == set(img_s.tensors)
        for k in img_d.tensors:
            assert np.array_equal(img_d[k], img_s[k]), k


@given(worlds())
@settings(max_examples=15, deadline=None)
def test_p2_resolution_deterministic(tmp_path_factory_world):
    bundles, refs = tmp_path_factory_world
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        reg, mgr, ex = _publish(tmp, bundles, refs)
        world = mgr.world()
        app = world.resolve("app")
        try:
            r1 = DynamicResolver(world).resolve(app)
        except Exception:
            return
        r2 = DynamicResolver(world).resolve(app)
        assert [
            (r.ref.name, r.provider.name if r.provider else None, int(r.rtype))
            for r in r1
        ] == [
            (r.ref.name, r.provider.name if r.provider else None, int(r.rtype))
            for r in r2
        ]


@given(worlds())
@settings(max_examples=15, deadline=None)
def test_p3_first_match_in_needed_order(tmp_path_factory_world):
    bundles, refs = tmp_path_factory_world
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        reg, mgr, ex = _publish(tmp, bundles, refs)
        world = mgr.world()
        app = world.resolve("app")
        try:
            rel = DynamicResolver(world).resolve(app)
        except Exception:
            return
        order = {n: i for i, (n, _) in enumerate(bundles)}
        by_name = {n: ts for n, ts in bundles}
        for r in rel:
            if r.provider is None:
                continue
            # no earlier bundle may export the same symbol
            for n, ts in bundles:
                if order[n] < order[r.provider.name]:
                    assert r.ref.name not in ts


@given(worlds())
@settings(max_examples=10, deadline=None)
def test_p4_table_roundtrip(tmp_path_factory_world):
    bundles, refs = tmp_path_factory_world
    import tempfile
    from pathlib import Path

    with tempfile.TemporaryDirectory() as tmp:
        reg, mgr, ex = _publish(tmp, bundles, refs)
        try:
            img = ex.load("app", strategy="stable")
        except Exception:
            return
        p = Path(tmp) / "t.npz"
        img.table.save(p)
        t2 = RelocationTable.load(p)
        assert np.array_equal(img.table.rows, t2.rows)
        assert img.table.strtab == t2.strtab


@given(
    st.lists(
        st.tuples(
            st.text("abcdef", min_size=1, max_size=5),
            st.integers(1, 500),
        ),
        min_size=1,
        max_size=20,
        unique_by=lambda t: t[0],
    )
)
@settings(max_examples=50, deadline=None)
def test_p5_arena_layout_disjoint_aligned(entries):
    refs = [SymbolRef(n, (k,), "float32") for n, k in entries]
    slots, size = build_arena_layout(refs)
    spans = sorted((s.offset, s.offset + s.nbytes) for s in slots.values())
    for (o, e), (o2, _) in zip(spans, spans[1:]):
        assert e <= o2
    for s in slots.values():
        assert s.offset % PAGE_BYTES == 0
    assert size >= max(e for _, e in spans)
