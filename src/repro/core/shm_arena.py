"""Cross-process shared arenas: one physical copy of a baked arena per machine.

PR 4's ``EpochCache`` made N same-*process* replicas share one read-only
arena mapping. This module extends the paper's "the epoch's relocation
mapping is immutable, so share it" argument across the process boundary:
each baked ``.arena`` image is published once into a named POSIX
shared-memory segment (``multiprocessing.shared_memory``), and every worker
process of a serving fleet *attaches* to that segment instead of paging the
file in privately — N processes, one physical copy, zero per-process fill.

Lifecycle / orphan-reclamation contract
=======================================

**Naming.** Segments are content-addressed: the name is a digest of
``(registry root, app hash, closure hash, generation)``. The *generation*
stamp is the digest of the arena's sidecar, so a re-baked arena (same
closure key, rewritten files) gets a fresh segment instead of silently
aliasing a stale one. Within one (root, app, closure, generation) the arena
bytes are deterministic, so any process may fill the segment and every
other process may trust it.

**Creation is exclusive, attach waits for ``ready``.** Exactly one process
wins the O_EXCL create; it writes a header (magic, generation, size), a
record file under ``<root>/shm/``, then the payload, and flips the header's
``ready`` byte *last*. Racing processes attach and poll ``ready`` (bounded
by ``fill_timeout``); a header whose generation or size disagrees is a
stale husk and is unlinked and re-created. A machine-checkable guarantee
rides on this: the ``ready`` byte asserts the segment is byte-identical to
the ``.arena`` image the resolver materialized (``tests/test_multiprocess``
verifies the identity from a second process).

**Segments deliberately outlive their creator.** Handles go through
``_posixshmem`` directly, bypassing the stdlib wrapper's resource tracker
(which would otherwise unlink the segment when the first registering
process exits — the opposite of a machine-wide cache — and whose
machine-shared cache races sibling processes' register/unregister pairs).
A segment therefore persists until explicitly unlinked; processes that
merely exit (or are SIGKILLed) leave the segment behind for the next
worker, exactly like the page cache keeps a mapped ELF warm.

**Reclamation is explicit and record-driven** (``Workspace.gc`` ->
``gc_segments``). Each creator writes ``<root>/shm/<segment>.json``
*before* filling (name, app/closure hashes, generation, size, creator
pid), so the garbage collector can census every segment this root ever
published, including half-filled husks of crashed creators. A segment is
unlinked when any of:

* its (app hash, closure hash) key is live in no world the caller honours
  (same liveness rule as ``Registry.gc_stores``), or
* its generation stamp no longer matches the on-disk sidecar (re-baked), or
* it never became ``ready`` and its creator pid is dead (crash mid-fill).

Live, ready segments are never touched — a fleet's warm state survives any
number of worker exits. ``shm_unlink`` only removes the name; a process
that still has the segment mapped (or died while mapped) keeps/loses its
mapping per normal POSIX semantics, so reclamation can never corrupt a
running reader — the unlinked-ELF analogy again.

**In-process handles are process-lifetime.** Attached segments are interned
in ``_LIVE_SEGMENTS`` so repeated loads (and epoch-cache refills after a
token bump) reuse one handle, and so no finalizer ever tries to unmap a
segment while numpy views over it are live. ``Workspace.close()`` on an
ephemeral root unlinks everything the root published.
"""

from __future__ import annotations

import atexit
import hashlib
import json
import mmap
import os
import struct
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Optional

import numpy as np

from .errors import StableLinkingError
from .objects import PAGE_BYTES, align_up

try:
    # The C primitive behind multiprocessing.shared_memory. Used directly
    # because the stdlib wrapper registers every handle (create AND attach)
    # with the multiprocessing resource tracker, which (a) unlinks tracked
    # segments when the first registering process exits — the opposite of a
    # machine-wide cache — and (b) keeps ONE tracker cache for all sibling
    # processes, so per-process balanced register/unregister pairs race and
    # spew KeyError noise. Segments here have an explicit, record-driven
    # lifecycle (see gc_segments); no tracker wanted.
    import _posixshmem
except ImportError:  # pragma: no cover - non-POSIX platform
    _posixshmem = None

SEGMENT_PREFIX = "repro-arena-"

# Header layout (one page, so the payload keeps the .arena file's page
# alignment): magic | ready byte | generation (16 raw bytes) | arena size.
HEADER_BYTES = PAGE_BYTES
_MAGIC = b"RPRARNA1"
_READY_OFF = 8
_GEN_OFF = 16
_SIZE_OFF = 32

# segment name -> SharedArenaSegment. Handles are interned for the life of
# the process (see module docstring); bounded by the number of distinct
# (app, closure, generation) arenas this process ever mapped.
_LIVE_SEGMENTS: dict[str, "SharedArenaSegment"] = {}
_LIVE_LOCK = threading.Lock()


class ShmArenaError(StableLinkingError):
    """A shared arena segment could not be published or attached."""


def generation_stamp(meta: dict) -> str:
    """The sidecar's content digest (32 hex chars / 16 raw bytes).

    Computed from the *parsed* sidecar re-serialized canonically, so every
    process derives the same stamp from the same file regardless of how it
    read it."""
    text = json.dumps(meta, sort_keys=True)
    return hashlib.blake2b(text.encode(), digest_size=16).hexdigest()


def segment_name(root, app_hash: str, closure_hash: str, generation: str) -> str:
    """Content-addressed segment name for one (root, app, closure, gen)."""
    h = hashlib.blake2b(digest_size=16)
    for part in (os.fspath(Path(root).resolve()), app_hash, closure_hash, generation):
        h.update(part.encode())
        h.update(b"\x00")
    return SEGMENT_PREFIX + h.hexdigest()


def shm_records_dir(registry) -> Path:
    """Where this root records the segments it published."""
    return registry.root / "shm"


def _require_posixshmem() -> None:
    if _posixshmem is None:  # pragma: no cover - non-POSIX platform
        raise ShmArenaError(
            "shared arena segments need POSIX shared memory "
            "(_posixshmem is unavailable on this platform)"
        )


class _SegmentNotReady(Exception):
    """Attached a segment its creator has not sized/filled yet (transient)."""


class _ShmHandle:
    """Minimal POSIX shared-memory handle (tracker-free by design).

    The stdlib ``SharedMemory`` minus the resource tracker (see the
    ``_posixshmem`` import note) and minus the noisy finalizer: ``close``
    tolerates live numpy exports by simply dropping its references — the
    mapping then lives exactly as long as the arrays over it, reclaimed by
    the C deallocators without a Python exception in sight."""

    __slots__ = ("name", "size", "_mmap", "_buf")

    def __init__(self, name: str, *, create: bool = False, size: int = 0):
        _require_posixshmem()
        flags = os.O_RDWR | (os.O_CREAT | os.O_EXCL if create else 0)
        fd = _posixshmem.shm_open("/" + name, flags, mode=0o600)
        try:
            if create and size:
                os.ftruncate(fd, size)
            self.size = os.fstat(fd).st_size
            if self.size == 0:
                # attach raced the creator between shm_open and ftruncate:
                # a zero-size file cannot be mapped — report it as the
                # transient it is, not a ValueError out of mmap
                raise _SegmentNotReady(name)
            self._mmap = mmap.mmap(fd, self.size)  # mmap keeps its own ref
        finally:
            os.close(fd)
        self._buf: Optional[memoryview] = memoryview(self._mmap)
        self.name = name

    @property
    def buf(self) -> memoryview:
        return self._buf

    def close(self) -> None:
        try:
            if self._buf is not None:
                self._buf.release()
            if self._mmap is not None:
                self._mmap.close()
        except BufferError:
            pass  # views still exported: mapping outlives this handle
        self._buf = None
        self._mmap = None


def _shm_unlink(name: str) -> bool:
    """Remove the name machine-wide (mappings survive, POSIX semantics)."""
    _require_posixshmem()
    try:
        _posixshmem.shm_unlink("/" + name)
    except FileNotFoundError:
        return False
    return True


@dataclass
class SharedArenaSegment:
    """One published arena segment, attached into this process.

    ``attached`` records whether this process found the segment already
    published (the fleet steady state) or had to create and fill it (the
    one fill the whole machine amortizes)."""

    shm: _ShmHandle
    name: str
    arena_size: int
    generation: str
    attached: bool

    def payload(self) -> np.ndarray:
        """Read-only uint8 view of the arena bytes (shared, zero-copy)."""
        if not self.arena_size:
            return np.empty(0, dtype=np.uint8)
        arr = np.frombuffer(
            self.shm.buf, dtype=np.uint8, count=self.arena_size,
            offset=HEADER_BYTES,
        )
        arr.flags.writeable = False
        return arr

    def close(self) -> None:
        """Best-effort unmap (process teardown only; see module docstring)."""
        with _LIVE_LOCK:
            _LIVE_SEGMENTS.pop(self.name, None)
        self.shm.close()


def _validate_header(
    shm: _ShmHandle, generation: str, arena_size: int
) -> str:
    """Classify an existing segment: 'ok' | 'filling' | 'stale'.

    The header writes in ``_fill`` (magic, then generation/size, then
    payload, then ready) are not atomic across processes, so generation
    and size are only judged once ``ready`` is set: before that, a
    mismatch just means we read mid-write — 'filling', never 'stale'
    (misclassifying would unlink a LIVE creator's segment and break the
    one-fill contract). Only a non-zero, non-magic prefix is immediately
    foreign/corrupt."""
    hdr = bytes(shm.buf[: _SIZE_OFF + 8])
    magic = hdr[:8]
    if magic == b"\x00" * 8:
        return "filling"  # creator won the race; header not written yet
    if magic != _MAGIC:
        return "stale"
    if hdr[_READY_OFF] != 1:
        return "filling"
    if (
        hdr[_GEN_OFF : _GEN_OFF + 16] != bytes.fromhex(generation)
        or struct.unpack("<Q", hdr[_SIZE_OFF : _SIZE_OFF + 8])[0] != arena_size
    ):
        return "stale"
    return "ok"


def _write_record(
    registry, name: str, app_hash: str, closure_hash: str,
    generation: str, size: int, arena_size: int, epoch_gen: int = -1,
) -> None:
    d = shm_records_dir(registry)
    d.mkdir(parents=True, exist_ok=True)
    rec = {
        "name": name,
        "app_hash": app_hash,
        "closure_hash": closure_hash,
        "generation": generation,
        "size": size,
        "arena_size": arena_size,
        "created_by_pid": os.getpid(),
        "created_ts": time.time(),
    }
    if epoch_gen >= 0:
        # observability only: which commit generation published this
        # segment (reclamation stays key/generation-stamp driven)
        rec["epoch_gen"] = epoch_gen
    tmp = d / f"{name}.json.tmp"
    tmp.write_text(json.dumps(rec, sort_keys=True))
    os.replace(tmp, d / f"{name}.json")


def _fill(
    shm: _ShmHandle, arena_path: Path,
    arena_size: int, generation: str,
) -> None:
    """Header (ready=0) -> payload -> ready=1. Readers trust ready alone."""
    mv = shm.buf
    mv[:HEADER_BYTES] = b"\x00" * HEADER_BYTES
    mv[:8] = _MAGIC
    mv[_GEN_OFF : _GEN_OFF + 16] = bytes.fromhex(generation)
    mv[_SIZE_OFF : _SIZE_OFF + 8] = struct.pack("<Q", arena_size)
    if arena_size:
        padded = align_up(arena_size, PAGE_BYTES)
        with open(arena_path, "rb") as f:
            f.readinto(memoryview(mv)[HEADER_BYTES : HEADER_BYTES + padded])
    mv[_READY_OFF] = 1


def _creator_alive(registry, name: str) -> bool:
    """Is the recorded creator of ``name`` still running?

    False when the record is missing or unreadable: a creator writes its
    record before filling, so a record-less segment past the fill deadline
    has no creator left to wait for."""
    try:
        rec = json.loads(
            (shm_records_dir(registry) / f"{name}.json").read_text()
        )
        return _pid_alive(int(rec.get("created_by_pid", 0)))
    except (OSError, ValueError):
        return False


def publish_or_attach(
    registry,
    app_hash: str,
    closure_hash: str,
    *,
    arena_path: Path,
    arena_size: int,
    generation: str,
    fill_timeout: float = 10.0,
    epoch_gen: int = -1,
) -> SharedArenaSegment:
    """The one entry point: return the machine-shared segment for this
    (app, closure, generation), publishing it if this process is first.

    Exactly one process can win the exclusive create; everyone else
    attaches and (if the creator is mid-fill) polls the ready byte. A husk
    that never becomes ready within ``fill_timeout`` — its creator died —
    is unlinked and re-created by whoever noticed."""
    name = segment_name(registry.root, app_hash, closure_hash, generation)
    with _LIVE_LOCK:
        live = _LIVE_SEGMENTS.get(name)
    if live is not None:
        return live
    total = HEADER_BYTES + align_up(arena_size, PAGE_BYTES)
    deadline = time.monotonic() + fill_timeout
    takeovers = 0
    # past-deadline creator-liveness probes are throttled: the record read
    # is a file open + json parse per call, and a legitimately slow
    # multi-GB fill would otherwise be probed ~500x/s by every waiter
    creator_alive, next_alive_probe = True, 0.0
    while True:
        try:
            shm = _ShmHandle(name, create=True, size=total)
        except FileExistsError:
            try:
                shm = _ShmHandle(name)
            except FileNotFoundError:
                continue  # raced an unlink between create and attach
            except _SegmentNotReady:
                shm = None  # creator between shm_open and ftruncate
            state = (
                _validate_header(shm, generation, arena_size)
                if shm is not None
                else "filling"
            )
            if state == "ok":
                seg = SharedArenaSegment(
                    shm=shm, name=name, arena_size=arena_size,
                    generation=generation, attached=True,
                )
                with _LIVE_LOCK:
                    _LIVE_SEGMENTS.setdefault(name, seg)
                    return _LIVE_SEGMENTS[name]
            now = time.monotonic()
            if state == "filling" and now >= deadline and now >= next_alive_probe:
                creator_alive = _creator_alive(registry, name)
                next_alive_probe = now + 0.5
            if state == "filling" and (now < deadline or creator_alive):
                # A creator is mid-fill: wait it out. Polls within the
                # deadline are expected (a multi-GB readinto legitimately
                # takes many of them); past the deadline we keep waiting as
                # long as the recorded creator pid is still alive — taking
                # over a LIVE creator's segment would break the
                # one-fill-per-machine contract and double the physical
                # copies. Only a dead creator's husk is taken over.
                if shm is not None:
                    shm.close()
                time.sleep(0.002)
                continue
            # stale/corrupt headers and dead-creator husks, by contrast,
            # should converge within a handful of unlink+recreate cycles
            takeovers += 1
            if takeovers > 8:
                raise ShmArenaError(
                    f"segment {name} kept reappearing stale/unready after "
                    f"{takeovers - 1} takeover attempts"
                )
            # stale generation/size, corrupt header, or a fill that never
            # completed (creator died): unlink the husk and take over
            _shm_unlink(name)
            if shm is not None:
                shm.close()
            continue
        # this process won the exclusive create: publish
        try:
            _write_record(
                registry, name, app_hash, closure_hash, generation,
                total, arena_size, epoch_gen,
            )
            _fill(shm, arena_path, arena_size, generation)
        except BaseException:
            _shm_unlink(name)
            shm.close()
            raise
        seg = SharedArenaSegment(
            shm=shm, name=name, arena_size=arena_size,
            generation=generation, attached=False,
        )
        with _LIVE_LOCK:
            _LIVE_SEGMENTS.setdefault(name, seg)
            return _LIVE_SEGMENTS[name]


@dataclass
class ShmArenaEntry:
    """Epoch-cache entry for one shared arena segment (section ``shm-arena``).

    The shm analogue of ``epoch_cache.ArenaEntry``: parsed sidecar +
    prebuilt read-only slot views, except the backing mapping is the
    machine-shared segment instead of a per-process file mapping. Pinned
    for the epoch (``cache_pinned``): the segment is mapped from creation,
    and evicting the entry would only drop the prebuilt views, not the
    machine-shared bytes."""

    segment: SharedArenaSegment
    meta: dict
    slot_items: list                 # (name, offset, nbytes, dtype, shape)
    arena_size: int
    kernels: dict
    sidecar_stat: tuple              # (mtime_ns, size) of the sidecar at fill
    ro_arena: Optional[np.ndarray] = None
    tensors: Optional[dict[str, np.ndarray]] = None
    _views_lock: threading.Lock = field(
        default_factory=threading.Lock, repr=False
    )

    @property
    def cache_nbytes(self) -> int:
        return self.arena_size

    @property
    def cache_pinned(self) -> bool:
        return True

    def shared_views(self) -> tuple[np.ndarray, dict[str, np.ndarray]]:
        tensors = self.tensors
        if tensors is not None:
            return self.ro_arena, tensors
        with self._views_lock:
            if self.tensors is not None:
                return self.ro_arena, self.tensors
            ro = self.segment.payload()
            self.ro_arena = ro
            self.tensors = {
                name: ro[off : off + nbytes].view(dt).reshape(shape)
                for name, off, nbytes, dt, shape in self.slot_items
            }
            return self.ro_arena, self.tensors


# ----------------------------------------------------------------- census/gc
def list_segments(registry) -> list[dict]:
    """Every segment record this root has published (census order)."""
    d = shm_records_dir(registry)
    out: list[dict] = []
    if not d.exists():
        return out
    for p in sorted(d.glob("*.json")):
        try:
            out.append(json.loads(p.read_text()))
        except (OSError, ValueError):
            continue
    return out


def segment_exists(name: str) -> bool:
    """Does the named segment exist on this machine right now?"""
    _require_posixshmem()
    try:
        fd = _posixshmem.shm_open("/" + name, os.O_RDONLY, mode=0o600)
    except FileNotFoundError:
        return False
    os.close(fd)
    return True


def _segment_ready(name: str) -> Optional[bool]:
    """Ready state of the named segment (None if it no longer exists)."""
    _require_posixshmem()
    try:
        fd = _posixshmem.shm_open("/" + name, os.O_RDONLY, mode=0o600)
    except FileNotFoundError:
        return None
    try:
        hdr = os.pread(fd, _READY_OFF + 1, 0)
        return len(hdr) > _READY_OFF and hdr[:8] == _MAGIC and hdr[_READY_OFF] == 1
    finally:
        os.close(fd)


def _pid_alive(pid: int) -> bool:
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    return True


def unlink_segment(name: str) -> bool:
    """Remove the named segment machine-wide (idempotent).

    Processes that still have it mapped keep their mapping — POSIX unlink
    semantics, same as a running binary surviving its ELF being deleted."""
    with _LIVE_LOCK:
        live = _LIVE_SEGMENTS.pop(name, None)
    found = _shm_unlink(name)
    if live is not None:
        live.shm.close()  # tolerant of views still exported
    return found


def gc_segments(
    registry,
    live_keys: Iterable[tuple[str, str]],
    *,
    dry_run: bool = False,
) -> tuple[list[str], int]:
    """Reclaim dead segments of this root (see module docstring's contract).

    ``live_keys`` is the same (app hash, closure key) live set
    ``Registry.gc_stores`` consumes. Returns (removed names, bytes).
    ``dry_run=True`` reports the same condemned segments without unlinking
    anything (segments or records) — the operator preflight."""
    live = {(a[:16], k[:16]) for a, k in live_keys}
    removed: list[str] = []
    bytes_reclaimed = 0
    d = shm_records_dir(registry)
    if not d.exists():
        return removed, bytes_reclaimed
    for rec_path in sorted(d.glob("*.json")):
        try:
            rec = json.loads(rec_path.read_text())
            name = rec["name"]
        except (OSError, ValueError, KeyError):
            continue  # unknown shapes in shm/ are left untouched
        if rec.get("kind") == "ring":
            # Data-plane rings (core.shm_ring) are session conduits, not
            # epoch caches: they live exactly as long as the process that
            # owns them. A dead owner — SIGKILLed dispatcher or worker —
            # condemns the segment regardless of content.
            from . import shm_ring

            if shm_ring.gc_ring_record(
                rec, pid_alive=_pid_alive, segment_ready=_segment_ready
            ):
                if dry_run:
                    if segment_exists(name):
                        removed.append(name)
                        bytes_reclaimed += int(rec.get("size", 0))
                    continue
                if unlink_segment(name):
                    removed.append(name)
                    bytes_reclaimed += int(rec.get("size", 0))
                rec_path.unlink(missing_ok=True)
            continue
        try:
            key = (str(rec["app_hash"])[:16], str(rec["closure_hash"])[:16])
        except KeyError:
            continue  # unknown shapes in shm/ are left untouched
        keep = key in live
        if keep:
            # re-baked since publication: the record's generation no longer
            # matches the sidecar this key would map today
            mpath = registry.arena_meta_path(
                rec["app_hash"], rec["closure_hash"]
            )
            try:
                current_gen = generation_stamp(json.loads(mpath.read_text()))
                keep = current_gen == rec.get("generation")
            except (OSError, ValueError):
                keep = False  # sidecar gone: nothing can validate an attach
        if keep:
            # crash mid-fill: never became ready and its creator is dead
            ready = _segment_ready(name)
            if ready is False and not _pid_alive(int(rec.get("created_by_pid", 0))):
                keep = False
            elif ready is None:
                # segment already gone (another root's gc, reboot): the
                # record is the orphan — drop it without counting bytes
                if not dry_run:
                    rec_path.unlink(missing_ok=True)
                continue
        if keep:
            continue
        if dry_run:
            if segment_exists(name):
                removed.append(name)
                bytes_reclaimed += int(rec.get("size", 0))
            continue
        if unlink_segment(name):
            removed.append(name)
            bytes_reclaimed += int(rec.get("size", 0))
        # already-gone segments (reboot, a sibling root's gc) drop only
        # their record — counting them would inflate bytes_reclaimed
        rec_path.unlink(missing_ok=True)
    return removed, bytes_reclaimed


def unlink_root_segments(registry) -> int:
    """Unlink every segment this root ever recorded (ephemeral teardown)."""
    n = 0
    for rec in list_segments(registry):
        if unlink_segment(rec.get("name", "")):
            n += 1
        (shm_records_dir(registry) / f"{rec.get('name', '')}.json").unlink(
            missing_ok=True
        )
    return n


@atexit.register
def _close_live_segments() -> None:  # pragma: no cover - interpreter exit
    """Release our mappings cleanly before interpreter teardown gets
    nondeterministic; the segments themselves stay published."""
    with _LIVE_LOCK:
        segs = list(_LIVE_SEGMENTS.values())
        _LIVE_SEGMENTS.clear()
    for seg in segs:
        try:
            seg.shm.close()
        except Exception:
            pass


# ------------------------------------------------------------------- fleet
def _fleet_worker(root, app_name, strategy, arch, max_new, barrier, queue,
                  store_url=None):
    """Spawn-target for one fleet replica (module-level: picklable by name).

    Imports stay inside the function so a load-only probe never pays the
    jax import; ``arch`` promotes the worker to a full ``ServeEngine``
    replica that generates ``max_new`` tokens after attaching. Failures are
    REPORTED, not swallowed: the worker pushes a structured error record
    (exception repr + traceback excerpt) so the parent's ``FleetReport``
    can name what died instead of timing out on silence."""
    import hashlib as _hashlib
    import os as _os
    import time as _time

    try:
        from repro.link import Workspace

        ws = Workspace.open(root)
        if store_url:
            # fleet warm-through-store: missing arenas are fetched
            # (verified, resumable, retried) before the shm publish
            ws.attach_store(store_url)
        barrier.wait(timeout=120)
        t0 = _time.perf_counter()
        image = ws.load(app_name, strategy=strategy)
        load_s = _time.perf_counter() - t0
        h = _hashlib.blake2b(digest_size=16)
        for tname in sorted(image.tensors):
            h.update(
                np.ascontiguousarray(image.tensors[tname]).view(np.uint8).tobytes()
            )
        result = {
            "pid": _os.getpid(),
            "strategy": strategy,
            "load_s": load_s,
            "cache_hit": bool(image.stats.cache_hit),
            "shm_attached": bool(image.stats.shm_attached),
            "segment": image.stats.shm_segment,
            "tensors_digest": h.hexdigest(),
        }
        if arch is not None:
            from repro.configs import get_config
            from repro.serve import ServeEngine

            cfg = get_config(arch, smoke=True)
            engine = ServeEngine.from_workspace(
                cfg, ws, app_name, strategy=strategy
            )
            rng = np.random.default_rng(0)
            prompts = rng.integers(0, cfg.vocab_size, (2, 8), dtype=np.int32)
            out, stats = engine.generate(prompts, max_new or 4)
            result["tokens_out"] = int(stats.tokens_out)
            result["sample"] = out[0, :4].tolist()
        queue.put(result)
    except BaseException as e:
        import traceback as _tb

        queue.put(
            {
                "pid": _os.getpid(),
                "strategy": strategy,
                "failed": True,
                "error": repr(e),
                "traceback": _tb.format_exc()[-2000:],
            }
        )
        raise


def run_fleet(
    root,
    app_name: str,
    *,
    processes: int = 2,
    strategy: str = "stable-shm",
    arch: Optional[str] = None,
    max_new: int = 0,
    timeout: float = 180.0,
    store_url: Optional[str] = None,
) -> list[dict]:
    """Spawn ``processes`` real OS worker processes that concurrently load
    ``app_name`` from the workspace at ``root`` and report back.

    The exclusive-create protocol guarantees at most ONE worker fills the
    segment; everyone else attaches — the machine-wide analogue of the
    EpochCache's one-fill-per-key contract. Returns one result dict per
    worker: successes carry (pid, segment, shm_attached, load_s,
    tensors_digest, ...); failures carry structured error records
    (``failed``, ``error``, ``traceback``, ``exit_code``) instead of
    stalling the fleet until the timeout — a crashed worker is accounted
    for the moment its process dies (SIGKILL included, in which case the
    record is synthesized from the exit code since the worker never got to
    report its own traceback)."""
    import multiprocessing as mp

    if processes < 1:
        raise ValueError("processes must be >= 1")
    ctx = mp.get_context("spawn")  # never fork a jax/XLA-initialized parent
    queue = ctx.Queue()
    barrier = ctx.Barrier(processes)
    procs = [
        ctx.Process(
            target=_fleet_worker,
            args=(os.fspath(root), app_name, strategy, arch, max_new,
                  barrier, queue, store_url),
            daemon=True,
        )
        for _ in range(processes)
    ]
    import queue as _queue

    deadline = time.monotonic() + timeout
    for p in procs:
        p.start()
    results: list[dict] = []
    synthesized: set[int] = set()  # pids whose death we recorded ourselves

    def reported_pids() -> set:
        return {r.get("pid") for r in results}

    try:
        while len(results) < len(procs) and time.monotonic() < deadline:
            try:
                results.append(queue.get(timeout=0.25))
                continue
            except _queue.Empty:
                pass
            # A dead worker that never reported is a failure record, not a
            # reason to ride out the timeout. Drain once more first: the
            # worker may have pushed its (success or error) record in the
            # instant before exiting.
            dead = [
                p for p in procs
                if not p.is_alive()
                and p.pid not in reported_pids()
                and p.pid not in synthesized
            ]
            if dead:
                try:
                    while True:
                        results.append(queue.get(timeout=0.25))
                except _queue.Empty:
                    pass
                seen = reported_pids()
                for p in dead:
                    if p.pid in seen:
                        continue
                    synthesized.add(p.pid)
                    results.append(
                        {
                            "pid": p.pid,
                            "strategy": strategy,
                            "failed": True,
                            "exit_code": p.exitcode,
                            "error": f"worker exited with code {p.exitcode} "
                                     "before reporting",
                        }
                    )
        for p in procs:
            p.join(timeout=max(0.1, deadline - time.monotonic()))
    finally:
        for p in procs:
            if p.is_alive():
                p.kill()
                p.join(timeout=5)
    # exit codes enrich the records of workers that DID report an error
    # before dying (their raise re-terminated the process non-zero)
    codes = {p.pid: p.exitcode for p in procs}
    for r in results:
        if r.get("failed") and "exit_code" not in r:
            r["exit_code"] = codes.get(r.get("pid"))
    if len(results) != len(procs):
        raise ShmArenaError(
            f"fleet: {len(results)}/{len(procs)} workers accounted for "
            f"(exit codes {[p.exitcode for p in procs]})"
        )
    return results
