"""Architecture + shape configuration.

One ``ModelConfig`` per assigned architecture (exact numbers from the
assignment table; sources cited in each arch file). ``reduced()`` derives the
CPU-smoke-test variant of any config: same family/topology, tiny dims.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | audio | vlm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # attention flavour
    qkv_bias: bool = False           # qwen1.5 QKV bias
    qk_norm: bool = False            # gemma3 / chameleon
    rope_theta: float = 10_000.0
    sliding_window: int = 0          # 0 = full attention everywhere
    global_every: int = 0            # gemma3: every Nth layer is global
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    use_bias: bool = False           # starcoder2: bias on all projections
    act: str = "silu"                # silu (SwiGLU) | gelu

    # MoE (d_ff above is the per-expert hidden dim for moe archs)
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0      # qwen2-moe: shared expert = n * d_ff wide
    capacity_factor: float = 1.25

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1

    # hybrid (zamba2): shared attention block every N backbone layers
    attn_every: int = 0

    # encoder-decoder (seamless): encoder layer count (0 = decoder-only)
    encoder_layers: int = 0

    # modality frontend stub: none | audio_frames | vq_tokens
    frontend: str = "none"

    dtype: str = "bfloat16"
    # activation rematerialization on the layer stack:
    #   nothing — recompute everything (min residency, max recompute)
    #   dots    — save matmul outputs, recompute elementwise
    #   none    — no remat (max residency, zero recompute)
    remat_policy: str = "nothing"

    # ----------------------------------------------------------- derived
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_ssm(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def supports_long_context(self) -> bool:
        """True for sub-quadratic archs (see DESIGN.md §4): SSM/hybrid decode
        is O(1)/token; gemma3's 5:1 sliding-window layers bound the cache."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests (full configs are only
    exercised via the dry-run's ShapeDtypeStructs)."""
    kw = dict(
        name=cfg.name + "-smoke",
        num_layers=max(2, min(4, cfg.attn_every + 1) if cfg.attn_every else 2),
        d_model=64,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.num_kv_heads, 2)),
        head_dim=16,
        d_ff=96 if not cfg.is_moe else 32,
        vocab_size=256,
        dtype="float32",
        rope_theta=cfg.rope_theta,
    )
    if cfg.is_moe:
        kw.update(num_experts=4, experts_per_token=2,
                  num_shared_experts=min(cfg.num_shared_experts, 1))
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=8)
    if cfg.attn_every:
        kw.update(attn_every=2, num_layers=4)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2)
    if cfg.sliding_window:
        kw.update(sliding_window=16, global_every=min(cfg.global_every, 2))
    return cfg.replace(**kw)
