"""zamba2-7b: hybrid 81L Mamba2 + shared attn [arXiv:2411.15242; unverified].

Selectable via ``--arch zamba2-7b``; reduced smoke variant via ``reduced(CONFIG)``.
"""

from .archs import ZAMBA2_7B as CONFIG
from .base import reduced

SMOKE = reduced(CONFIG)

__all__ = ["CONFIG", "SMOKE"]
