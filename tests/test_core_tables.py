"""Relocation tables: build/roundtrip, strtab, page-table compilation."""

import numpy as np

from repro.core import (
    DynamicResolver,
    PAGE_BYTES,
    RelocType,
    SymbolRef,
    build_table,
    compile_page_table,
)
from repro.core.relocation import RelocationTable

from conftest import build_app, build_bundle


def _materialized(linker, tensors, refs):
    _, mgr, ex = linker
    bundle, payload = build_bundle("lib", tensors)
    app = build_app("app", refs, ["lib"])
    mgr.update_obj(bundle, payload)
    mgr.update_obj(app)
    mgr.end_mgmt()
    img = ex.load("app", strategy="stable")
    return img, mgr, ex


def test_table_roundtrip(linker, tmp_path):
    tensors = {
        "a": np.arange(16, dtype=np.float32),
        "b": np.ones((2, 3), np.int32),
    }
    refs = [
        SymbolRef("a", (16,), "float32"),
        SymbolRef("b", (2, 3), "int32"),
        SymbolRef("w", (4,), "float32", weak=True),
    ]
    img, mgr, ex = _materialized(linker, tensors, refs)
    t = img.table
    p = tmp_path / "t.npz"
    t.save(p)
    t2 = RelocationTable.load(p)
    assert np.array_equal(t.rows, t2.rows)
    assert t.strtab == t2.strtab
    assert t.meta == t2.meta
    # string reconstitution
    names = {t2.name_at(r["symbol_name"]) for r in t2.rows}
    assert names == {"a", "b", "w"}


def test_arena_slots_page_aligned_and_disjoint(linker):
    tensors = {f"t{i}": np.full(100 + i, i, np.float32) for i in range(5)}
    refs = [SymbolRef(f"t{i}", (100 + i,), "float32") for i in range(5)]
    img, *_ = _materialized(linker, tensors, refs)
    slots = sorted(img.table.slots().values(), key=lambda s: s.offset)
    for i, s in enumerate(slots):
        assert s.offset % PAGE_BYTES == 0
        if i:
            prev = slots[i - 1]
            assert prev.offset + prev.nbytes <= s.offset


def test_page_table_equivalent_to_host_load(linker):
    rng = np.random.default_rng(0)
    tensors = {
        f"t{i}": rng.standard_normal(256 * (i + 1)).astype(np.float32)
        for i in range(6)
    }
    refs = [
        SymbolRef(f"t{i}", (256 * (i + 1),), "float32") for i in range(6)
    ]
    img, mgr, ex = _materialized(linker, tensors, refs)
    pt = compile_page_table(img.table)
    assert len(pt.host_rows) == 0  # all DIRECT page-aligned
    # reconstruct via page copy
    blob = np.zeros(pt.blob_pages * PAGE_BYTES, np.uint8)
    for o in img.table.objects:
        if o["payload_size"] == 0:
            continue
        raw = np.fromfile(
            ex.registry.root / "objects" / o["store_name"] / "payload.bin",
            np.uint8,
        )
        start = pt.blob_layout[int(o["uuid"])] * PAGE_BYTES
        blob[start : start + len(raw)] = raw
    arena = np.zeros(pt.arena_pages * PAGE_BYTES, np.uint8)
    arena.reshape(-1, PAGE_BYTES)[pt.dst_page] = blob.reshape(-1, PAGE_BYTES)[
        pt.src_page
    ]
    for name, slot in img.table.slots().items():
        got = arena[slot.offset : slot.offset + slot.nbytes].view(np.float32)
        assert np.array_equal(got, tensors[name])


def test_page_table_routes_cast_and_init_to_host(linker):
    tensors = {"x": np.ones(8, np.float64)}
    refs = [
        SymbolRef("x", (8,), "float32"),                  # CAST
        SymbolRef("z", (8,), "float32", weak=True),       # INIT
    ]
    img, *_ = _materialized(linker, tensors, refs)
    pt = compile_page_table(img.table)
    assert len(pt.host_rows) == 2
    assert len(pt.dst_page) == 0


def test_uuid_stability_across_builds(linker):
    """Content-addressed UUIDs: same content -> same uuid (DESIGN §7)."""
    b1, _ = build_bundle("lib", {"a": np.arange(4, dtype=np.float32)})
    b2, _ = build_bundle("lib", {"a": np.arange(4, dtype=np.float32)})
    b3, _ = build_bundle("lib", {"a": np.arange(5, dtype=np.float32)})
    assert b1.uuid == b2.uuid
    assert b1.uuid != b3.uuid
