"""The unified session API: Workspace transactions, strategy registry,
and LinkReport observability."""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import (
    Manager,
    Mode,
    ModeError,
    StableLinkingError,
    SymbolRef,
    UnknownStrategyError,
)
from repro.link import (
    Workspace,
    available_strategies,
    register_strategy,
    strategy_overrides,
    unregister_strategy,
)

from conftest import build_app, build_bundle


def _publish_demo(ws, value=1.0, version="1"):
    tensors = {
        "s/a": np.full(8, value, np.float32),
        "s/b": np.arange(6, dtype=np.float32).reshape(2, 3),
    }
    bundle = build_bundle("w", tensors, version=version)
    app = build_app(
        "app",
        [
            SymbolRef("s/a", (8,), "float32"),
            SymbolRef("s/b", (2, 3), "float32"),
        ],
        ["w"],
    )
    with ws.management() as tx:
        tx.publish(*bundle)
        tx.publish(app)
    return tensors


# ----------------------------------------------------------- transactions
def test_commit_materializes_and_bumps_epoch(workspace):
    ws = workspace
    assert ws.epoch == 0 and ws.mode == Mode.MANAGEMENT
    tensors = _publish_demo(ws)
    assert ws.epoch == 1 and ws.mode == Mode.EPOCH
    img = ws.load("app")  # auto -> stable during the epoch
    assert img.stats.strategy == "stable"
    np.testing.assert_array_equal(img["s/a"], tensors["s/a"])


def test_rollback_restores_pre_transaction_state(workspace):
    ws = workspace
    _publish_demo(ws)
    epoch = ws.epoch
    bindings = ws.world().bindings
    baseline = {k: np.array(v) for k, v in ws.load("app").tensors.items()}

    class Boom(Exception):
        pass

    with pytest.raises(Boom):
        with ws.management() as tx:
            tx.remove("w")
            tx.publish(*build_bundle("w2", {"s/z": np.zeros(4, np.float32)}))
            assert "w" not in tx.world()
            raise Boom()

    assert ws.epoch == epoch
    assert ws.mode == Mode.EPOCH
    assert ws.world().bindings == bindings
    img = ws.load("app")
    for name, arr in baseline.items():
        np.testing.assert_array_equal(np.asarray(img[name]), arr, err_msg=name)


def test_commit_time_materialization_failure_rolls_back(workspace):
    """An unresolvable app staged in a transaction fails at end_mgmt's
    materialization; the failure must not half-commit the staged world."""
    from repro.core import UnresolvedSymbolError

    ws = workspace
    _publish_demo(ws)
    epoch = ws.epoch
    bindings = ws.world().bindings
    bad_app = build_app(
        "bad", [SymbolRef("missing/sym", (4,), "float32")], ["w"]
    )
    with pytest.raises(UnresolvedSymbolError):
        with ws.management() as tx:
            tx.publish(bad_app)
    assert ws.epoch == epoch
    assert ws.mode == Mode.EPOCH
    assert ws.world().bindings == bindings
    assert ws.load("app").stats.strategy == "stable"


def test_rollback_on_virgin_workspace_stays_in_management(workspace):
    ws = workspace
    with pytest.raises(RuntimeError):
        with ws.management() as tx:
            tx.publish(*build_bundle("w", {"s/a": np.ones(4, np.float32)}))
            raise RuntimeError()
    # no epoch was ever committed: nothing to return to
    assert ws.epoch == 0 and ws.mode == Mode.MANAGEMENT
    assert ws.world().bindings == {}


def test_transaction_handle_closes_after_exit(workspace):
    ws = workspace
    with ws.management() as tx:
        tx.publish(*build_bundle("w", {"s/a": np.ones(4, np.float32)}))
    assert tx.epoch == 1
    assert not tx.active
    with pytest.raises(ModeError):
        tx.publish(*build_bundle("x", {"s/a": np.ones(4, np.float32)}))


def test_management_restarts_clean_over_crashed_pending(tmp_path):
    """A leftover pending snapshot is not silently committed by the next
    transaction (resume=True opts in explicitly)."""
    ws = Workspace.open(tmp_path / "store")
    _publish_demo(ws)
    # simulate a crash mid-management: staged removal persisted, process died
    ws.manager.begin_mgmt()
    ws.manager.remove_obj("w")
    ws2 = Workspace.open(tmp_path / "store")  # new process, same store
    with ws2.management() as tx:
        pass  # default: starts from the committed world, not the pending one
    assert "w" in ws2.world()
    assert "app" in ws2.world()


def test_stale_pending_cannot_leak_into_epoch_state(tmp_path):
    ws = Workspace.open(tmp_path / "store")
    _publish_demo(ws)
    # hand-corrupt the state file: epoch mode but a half-staged pending
    state = json.loads(ws.registry.state_path.read_text())
    state["pending"] = {}
    ws.registry.state_path.write_text(json.dumps(state))
    mgr = Manager(Workspace.open(tmp_path / "store").registry)
    assert mgr.world().bindings == state["world"]
    mgr.begin_mgmt()
    assert mgr.world().bindings == state["world"]  # staged starts from world


def test_abort_mgmt_outside_management_raises(workspace):
    _publish_demo(workspace)
    with pytest.raises(ModeError):
        workspace.manager.abort_mgmt()


# ------------------------------------------------------- strategy registry
def test_auto_dispatch_follows_mode(workspace):
    ws = workspace
    tensors = {"s/a": np.ones(8, np.float32)}
    with ws.management() as tx:
        tx.publish(*build_bundle("w", tensors))
        tx.publish(build_app("app", [SymbolRef("s/a", (8,), "float32")], ["w"]))
        img = ws.load("app")  # management time -> indexed (per-load resolve)
        assert img.stats.strategy == "indexed"
        np.testing.assert_array_equal(img["s/a"], tensors["s/a"])
    img = ws.load("app")      # epoch -> stable
    assert img.stats.strategy == "stable"


def test_unknown_strategy_raises_stable_linking_error(workspace):
    _publish_demo(workspace)
    with pytest.raises(UnknownStrategyError) as exc:
        workspace.load("app", strategy="warp-speed")
    assert isinstance(exc.value, StableLinkingError)
    for name in ("stable", "dynamic", "lazy"):
        assert name in str(exc.value)


def test_registered_strategy_is_drop_in(workspace):
    ws = workspace
    tensors = _publish_demo(ws)
    calls = []

    @register_strategy("counting-stable")
    def _counting(executor, app, world):
        calls.append(app.name)
        return executor._load_stable(app, world)

    try:
        assert "counting-stable" in available_strategies()
        img = ws.load("app", strategy="counting-stable")
        np.testing.assert_array_equal(img["s/a"], tensors["s/a"])
        assert calls == ["app"]
    finally:
        unregister_strategy("counting-stable")
    assert "counting-stable" not in available_strategies()


def test_strategy_overrides_shadow_builtin_without_leaking(workspace):
    """Shadowing `stable` used to leak for the rest of the process; the
    context manager restores the exact previous registry, even when the
    body raises."""
    ws = workspace
    tensors = _publish_demo(ws)
    calls = []

    def counting_stable(executor, app, world):
        calls.append(app.name)
        return executor._load_stable(app, world)

    builtin = __import__(
        "repro.link.strategies", fromlist=["_stable"]
    )._stable
    with strategy_overrides(stable=counting_stable, lazy=None):
        img = ws.load("app", strategy="stable")
        assert calls == ["app"]
        assert "lazy" not in available_strategies()
        np.testing.assert_array_equal(img["s/a"], tensors["s/a"])
    from repro.link import get_strategy

    assert get_strategy("stable") is builtin       # built-in restored
    assert "lazy" in available_strategies()
    ws.load("app", strategy="stable")
    assert calls == ["app"]                        # shadow is gone

    with pytest.raises(RuntimeError):
        with strategy_overrides(stable=counting_stable):
            raise RuntimeError("body blew up")
    assert get_strategy("stable") is builtin       # restored on exception


def test_builtin_strategies_agree(workspace):
    ws = workspace
    _publish_demo(ws)
    stable = ws.load("app", strategy="stable")
    dynamic = ws.load("app", strategy="dynamic")
    prefetch = ws.load("app", strategy="prefetch")
    lazy = ws.load("app", strategy="lazy")
    for name in stable.tensors:
        a = np.asarray(stable[name])
        np.testing.assert_array_equal(a, np.asarray(dynamic[name]))
        np.testing.assert_array_equal(a, np.asarray(prefetch[name]))
        np.testing.assert_array_equal(a, np.asarray(lazy[name]))


# --------------------------------------------------------------- explain
def test_explain_reads_materialized_table_mid_epoch(workspace):
    ws = workspace
    _publish_demo(ws)
    rep = ws.explain("app")
    assert rep.source == "materialized-table"
    assert rep.epoch == 1
    assert rep.relocations == 2
    assert rep.by_type == {"DIRECT": 2}
    assert rep.providers == {"w": 2}
    assert rep.world_hash == ws.world().world_hash
    assert rep.stats is None  # nothing loaded through the workspace yet

    ws.load("app")
    rep2 = ws.explain("app")
    assert rep2.stats is not None and rep2.stats.strategy == "stable"
    assert rep2.summary()["last_load"]["relocations"] == 2

    conn = rep2.to_sqlite()
    n = conn.execute("SELECT COUNT(*) FROM relocations").fetchone()[0]
    assert n == 2
    assert len(rep2.records()) == 2
    assert "s/a" in rep2.to_csv()


def test_explain_tracks_epoch_bump(workspace):
    ws = workspace
    _publish_demo(ws, value=1.0, version="1")
    rep1 = ws.explain("app")
    _publish_demo(ws, value=2.0, version="2")  # upgrade bundle -> new epoch
    rep2 = ws.explain("app")
    assert rep2.epoch == rep1.epoch + 1
    assert rep2.world_hash != rep1.world_hash
    assert rep2.source == "materialized-table"
    img = ws.load("app")
    np.testing.assert_array_equal(img["s/a"], np.full(8, 2.0, np.float32))


def test_explain_previews_staged_world_during_management(workspace):
    ws = workspace
    _publish_demo(ws)
    with ws.management() as tx:
        tx.publish(*build_bundle("w", {
            "s/a": np.full(8, 9.0, np.float32),
            "s/b": np.zeros((2, 3), np.float32),
        }, version="9"))
        rep = tx and ws.explain("app")
        assert rep.mode == "management"
        assert rep.source == "dynamic-resolution"  # no table committed yet
    assert ws.explain("app").source == "materialized-table"
