"""Parameter specifications: the models' *symbol manifests*.

Every model declares its parameters as ``{name: ParamSpec}`` — shape, dtype,
logical sharding axes, and initializer — WITHOUT allocating anything. This
single declaration drives:

* stable linking  — the spec dict converts 1:1 into ``SymbolRef``s (the
  application's relocation instructions) and into bundle symbol tables;
* initialization  — per-name key folding makes init order-independent;
* sharding        — logical axes resolve through dist.sharding rules;
* the dry-run     — ``jax.ShapeDtypeStruct`` stand-ins, no allocation.

Names are canonical `/`-separated paths; stacked-layer params carry the
leading "layers" logical axis (bundle-side these become stacked symbols,
loadable per-slice via RelocType.SLICE).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Callable, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    dtype: str
    axes: tuple[Optional[str], ...]      # logical axis names, len == ndim
    init: str = "normal"                 # normal | zeros | ones | fan_in

    def __post_init__(self):
        assert len(self.axes) == len(self.shape), (self.axes, self.shape)


def _name_key(base: jax.Array, name: str) -> jax.Array:
    h = int.from_bytes(hashlib.blake2b(name.encode(), digest_size=4).digest(), "big")
    return jax.random.fold_in(base, h)


def _init_one(key: jax.Array, spec: ParamSpec) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "fan_in":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = fan_in ** -0.5
    else:
        std = 0.02
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dt)


def init_params(
    specs: Mapping[str, ParamSpec], seed: int = 0
) -> dict[str, jax.Array]:
    """Order-independent initialization: each param's key is derived from its
    name, so adding/removing symbols never perturbs its neighbours."""
    base = jax.random.key(seed)
    return {n: _init_one(_name_key(base, n), s) for n, s in specs.items()}


def init_params_np(
    specs: Mapping[str, ParamSpec], seed: int = 0
) -> dict[str, np.ndarray]:
    return {n: np.asarray(v) for n, v in init_params(specs, seed).items()}


def abstract_params(specs: Mapping[str, ParamSpec]) -> dict[str, jax.ShapeDtypeStruct]:
    return {
        n: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype))
        for n, s in specs.items()
    }


def param_bytes(specs: Mapping[str, ParamSpec]) -> int:
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in specs.values()
    )


def param_count(specs: Mapping[str, ParamSpec]) -> int:
    return sum(int(np.prod(s.shape)) for s in specs.values())
