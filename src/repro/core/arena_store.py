"""Tiered, content-addressed arena store: one machine bakes, a fleet fetches.

The paper's fleet-scale payoff (shared artifacts, not per-machine dynamic
linking) needs baked ``.arena`` images to move between machines. This
module chains three tiers under one call:

1. **tables/** — the machine already has the baked arena (the baker, or a
   previous fetch): nothing to do.
2. **local store cache** (``<root>/store/``) — a previously fetched and
   *verified* blob is decoded and installed without touching the network.
3. **remote store** — a minimal HTTP object store (``repro.launch.store``)
   is asked for the blob; the fetch path is robustness-first (below).
4. **fallback bake** — the remote is unreachable past the retry budget
   and the machine has the payloads locally: bake instead of wedging, and
   surface ``degraded=True`` in the :class:`StoreReport`.

The fetch path treats the remote as untrusted and the network as flaky:

* per-request connect/read timeouts (:class:`FetchPolicy`);
* capped exponential backoff with full jitter and a total retry budget
  per blob;
* resumable downloads — a truncated transfer leaves ``partial/<digest>.part``
  and the next attempt continues with an HTTP ``Range: bytes=N-`` read
  (``fetch_resumed`` counts these) instead of starting over;
* **mandatory content verification**: the blob frame is decoded
  (:mod:`repro.dist.compression`) and the raw bytes' blake2b digest must
  match the index entry before anything is admitted to the local tier.
  A mismatch (flipped bytes, short frame, bogus codec) moves the bytes to
  ``<root>/store/quarantine/`` with a structured JSON record — quarantined
  bytes are never resumed or re-served, and ``ws.gc()`` reclaims them;
* installation into ``tables/`` is atomic (unique temp file +
  ``os.replace``, arena before sidecar) so a crash mid-install can never
  leave an adoptable half-arena — the sidecar's presence is the commit.

Store-on-disk layout (identical for the serving and fetching side, so any
fetcher can later be promoted to a baker/server)::

    <root>/store/
      index.json            entries keyed "<app16>-<key16>" (see below)
      blobs/<digest>        framed blob (repro.dist.compression frame)
      partial/<digest>.part in-flight downloads (fetcher only)
      quarantine/           rejected bytes + structured records (fetcher)
      remote-index.json     last verified remote index (fetcher)

An index entry carries everything install needs: the sidecar JSON inline
(small), the raw-byte digest, raw/encoded sizes, and the codec name.
"""

from __future__ import annotations

import hashlib
import json
import os
import random
import shutil
import threading
import time
import urllib.error
import urllib.request
from dataclasses import dataclass, field
from http.client import HTTPException
from pathlib import Path
from typing import Optional

from repro.dist.compression import CodecError, decode_bytes, encode_bytes

from .errors import StableLinkingError
from .registry import Registry


class ArenaStoreError(StableLinkingError):
    """The store tier could not produce a verified arena (budget exhausted,
    entry absent, or the fallback bake was impossible too)."""


def blob_digest(raw: bytes) -> str:
    """Content address of RAW (decoded) arena bytes — blake2b-128 like
    every other digest in the store."""
    return hashlib.blake2b(bytes(raw), digest_size=16).hexdigest()


def pair_key(app_hash: str, key: str) -> str:
    return f"{app_hash[:16]}-{key[:16]}"


@dataclass
class FetchPolicy:
    """Knobs of the robust fetch path. Defaults suit a LAN store; tests
    shrink everything so chaos runs stay fast."""

    connect_timeout_s: float = 2.0   # also the read timeout per request
    read_timeout_s: float = 5.0
    retry_budget: int = 5            # total retries per blob, all causes
    backoff_base_s: float = 0.05     # first backoff; doubles per retry
    backoff_max_s: float = 2.0       # cap on any single backoff
    jitter: float = 1.0              # 0..1: fraction of the backoff drawn
    chunk_bytes: int = 1 << 18       # stream granularity (256 KiB)


@dataclass
class StoreReport:
    """Counters of one store session (attach → warmup/loads → gc)."""

    degraded: bool = False        # at least one blob came from fallback bake
    fetch_attempts: int = 0       # HTTP requests issued (index + blobs)
    fetch_retries: int = 0        # attempts beyond the first, per blob/index
    fetch_resumed: int = 0        # range-read continuations of a partial
    quarantined: int = 0          # blobs rejected by verification
    fallback_bakes: int = 0       # arenas baked locally after fetch failure
    blobs_fetched: int = 0        # verified blobs admitted from the remote
    bytes_fetched: int = 0        # encoded bytes pulled off the wire
    raw_bytes: int = 0            # decoded bytes those blobs expanded to
    cache_hits: int = 0           # served from <root>/store/blobs
    tables_hits: int = 0          # arena already baked in tables/
    errors: list[str] = field(default_factory=list)

    def summary(self) -> dict:
        return {
            "degraded": self.degraded,
            "fetch_attempts": self.fetch_attempts,
            "fetch_retries": self.fetch_retries,
            "fetch_resumed": self.fetch_resumed,
            "quarantined": self.quarantined,
            "fallback_bakes": self.fallback_bakes,
            "blobs_fetched": self.blobs_fetched,
            "bytes_fetched": self.bytes_fetched,
            "raw_bytes": self.raw_bytes,
            "cache_hits": self.cache_hits,
            "tables_hits": self.tables_hits,
            "errors": list(self.errors),
        }


# ----------------------------------------------------------------- layout
def store_dir(registry: Registry) -> Path:
    return registry.root / "store"


def _index_path(registry: Registry) -> Path:
    return store_dir(registry) / "index.json"


class _CorruptBlob(Exception):
    """Verification failed — quarantine, never admit, never resume."""

    def __init__(self, reason: str, actual: str = ""):
        self.reason = reason
        self.actual = actual
        super().__init__(reason)


# retryable transport failures: refused/reset connections, timeouts,
# truncated responses, DNS blips. Everything content-shaped goes through
# verification instead and quarantines on mismatch.
_RETRYABLE = (urllib.error.URLError, HTTPException, ConnectionError,
              TimeoutError, OSError, EOFError)


# ----------------------------------------------------------------- export
def export_store(registry: Registry, *, codec: str = "zlib") -> dict:
    """Publish every fully baked (arena + sidecar) pair in ``tables/``
    into ``<root>/store/`` as content-addressed blobs + an index.

    Idempotent and incremental: blobs are content-addressed so re-export
    after a commit only encodes the new pairs. Returns a summary dict
    (entries, raw/encoded byte totals, codec)."""
    sdir = store_dir(registry)
    blobs = sdir / "blobs"
    blobs.mkdir(parents=True, exist_ok=True)
    entries: dict[str, dict] = {}
    raw_total = encoded_total = 0
    tables = registry.root / "tables"
    for mpath in sorted(tables.glob("*.arena.json")) if tables.exists() else []:
        apath = mpath.with_suffix("")  # strip .json -> .arena
        if not apath.exists():
            continue  # half-baked pair: never served
        sidecar = json.loads(mpath.read_text())
        raw = apath.read_bytes()
        digest = blob_digest(raw)
        frame = encode_bytes(raw, codec)
        bpath = blobs / digest
        if not bpath.exists():
            tmp = bpath.with_name(f".{digest}.{os.getpid()}.tmp")
            tmp.write_bytes(frame)
            os.replace(tmp, bpath)
        pair = apath.name[: -len(".arena")]
        entries[pair] = {
            "app": sidecar.get("app", ""),
            "app_hash": sidecar.get("app_hash", ""),
            "closure_hash": sidecar.get("closure_hash", ""),
            "digest": digest,
            "raw_bytes": len(raw),
            "blob_bytes": len(frame),
            "codec": codec,
            "sidecar": sidecar,
        }
        raw_total += len(raw)
        encoded_total += len(frame)
    index = {"schema": 1, "codec": codec, "entries": entries}
    tmp = _index_path(registry).with_suffix(".tmp")
    tmp.write_text(json.dumps(index, indent=1, sort_keys=True))
    os.replace(tmp, _index_path(registry))
    return {
        "entries": len(entries),
        "raw_bytes": raw_total,
        "blob_bytes": encoded_total,
        "codec": codec,
        "path": str(sdir),
    }


# --------------------------------------------------------------- local tier
class LocalStoreCache:
    """The verified half of ``<root>/store/`` on a fetching machine."""

    def __init__(self, sdir: Path):
        self.dir = Path(sdir)
        self.blobs = self.dir / "blobs"
        self.partial = self.dir / "partial"
        self.quarantine_dir = self.dir / "quarantine"

    def blob_path(self, digest: str) -> Path:
        return self.blobs / digest

    def has_blob(self, digest: str) -> bool:
        return self.blob_path(digest).exists()

    def partial_path(self, digest: str) -> Path:
        # per-pid: two fleet processes sharing one root must never
        # interleave appends into the same resume buffer. A crash orphans
        # the file; gc_store_dirs reclaims it.
        return self.partial / f"{digest}.{os.getpid()}.part"

    def admit(self, part: Path, digest: str) -> Path:
        """Atomically promote a VERIFIED partial file into blobs/."""
        self.blobs.mkdir(parents=True, exist_ok=True)
        dest = self.blob_path(digest)
        os.replace(part, dest)
        return dest

    def quarantine(
        self, part: Path, *, digest: str, reason: str,
        actual: str = "", url: str = "",
    ) -> Path:
        """Move rejected bytes out of the fetch path, with a record.

        The ``.bad`` file keeps the evidence for debugging; the ``.json``
        record is the structured audit entry. Nothing under quarantine/
        is ever read back by the fetch path — a fresh attempt restarts
        from byte zero."""
        self.quarantine_dir.mkdir(parents=True, exist_ok=True)
        n = 0
        while True:
            base = self.quarantine_dir / f"{digest}-{n}"
            if not base.with_suffix(".bad").exists():
                break
            n += 1
        bad = base.with_suffix(".bad")
        size = 0
        if part.exists():
            size = part.stat().st_size
            os.replace(part, bad)
        else:  # pragma: no cover - defensive: record even without bytes
            bad.write_bytes(b"")
        record = {
            "digest_expected": digest,
            "digest_actual": actual,
            "reason": reason,
            "bytes": size,
            "url": url,
            "ts": time.time(),
            "pid": os.getpid(),
        }
        base.with_suffix(".json").write_text(
            json.dumps(record, indent=1, sort_keys=True)
        )
        return bad


def gc_store_dirs(registry: Registry, *, dry_run: bool = False) -> tuple[list[str], int]:
    """Reclaim the disposable halves of ``<root>/store/``: quarantine
    records and stale partial downloads. Returns (names, bytes).

    Verified blobs and the cached remote index are the warm tier and are
    deliberately kept. Callable whether or not a store was ever attached
    (``Workspace.gc`` always runs it)."""
    removed: list[str] = []
    nbytes = 0
    sdir = store_dir(registry)
    for sub in ("quarantine", "partial"):
        d = sdir / sub
        if not d.exists():
            continue
        for p in sorted(d.iterdir()):
            if not p.is_file():
                continue
            nbytes += p.stat().st_size
            removed.append(f"store/{sub}/{p.name}")
            if not dry_run:
                p.unlink()
    return removed, nbytes


# -------------------------------------------------------------- remote tier
class RemoteStoreClient:
    """Robust HTTP reads against a served store (index + range-read blobs)."""

    def __init__(self, url: str, policy: FetchPolicy, report: StoreReport):
        self.url = url.rstrip("/")
        self.policy = policy
        self.report = report

    # ------------------------------------------------------------- plumbing
    def _open(self, path: str, *, range_start: int = 0):
        req = urllib.request.Request(f"{self.url}{path}")
        if range_start:
            req.add_header("Range", f"bytes={range_start}-")
        self.report.fetch_attempts += 1
        return urllib.request.urlopen(
            req, timeout=max(self.policy.connect_timeout_s,
                             self.policy.read_timeout_s)
        )

    def _backoff(self, attempt: int) -> None:
        p = self.policy
        span = min(p.backoff_base_s * (2 ** attempt), p.backoff_max_s)
        if p.jitter:
            span = span * (1.0 - p.jitter) + random.uniform(0, span * p.jitter)
        time.sleep(span)

    # ---------------------------------------------------------------- index
    def fetch_index(self) -> dict:
        last: Exception | None = None
        for attempt in range(self.policy.retry_budget + 1):
            if attempt:
                self.report.fetch_retries += 1
                self._backoff(attempt - 1)
            try:
                with self._open("/index.json") as resp:
                    return json.loads(resp.read().decode())
            except (json.JSONDecodeError, *_RETRYABLE) as e:
                last = e
        raise ArenaStoreError(
            f"store index unreachable after {self.policy.retry_budget} "
            f"retries: {self.url}/index.json ({last!r})"
        )

    # ---------------------------------------------------------------- blobs
    def fetch_blob(self, entry: dict, cache: LocalStoreCache) -> bytes:
        """Fetch + verify one blob; returns the RAW (decoded) bytes.

        Resumes partial downloads, quarantines anything that fails
        verification, and raises :class:`ArenaStoreError` once the retry
        budget is spent."""
        digest = entry["digest"]
        blob_bytes = int(entry["blob_bytes"])
        url = f"{self.url}/blobs/{digest}"
        part = cache.partial_path(digest)
        last: Exception | None = None
        for attempt in range(self.policy.retry_budget + 1):
            if attempt:
                self.report.fetch_retries += 1
                self._backoff(attempt - 1)
            try:
                self._download_once(url, digest, part, blob_bytes)
                frame = part.read_bytes()
                try:
                    raw = decode_bytes(frame)
                except CodecError as e:
                    raise _CorruptBlob(f"frame does not decode: {e}") from e
                actual = blob_digest(raw)
                if actual != digest:
                    raise _CorruptBlob("content digest mismatch", actual)
                cache.admit(part, digest)
                self.report.blobs_fetched += 1
                self.report.bytes_fetched += len(frame)
                self.report.raw_bytes += len(raw)
                return raw
            except _CorruptBlob as e:
                # bytes leave the fetch path entirely; next attempt
                # restarts from zero (never resume quarantined bytes)
                cache.quarantine(
                    part, digest=digest, reason=e.reason,
                    actual=e.actual, url=url,
                )
                self.report.quarantined += 1
                last = e
            except _RETRYABLE as e:
                last = e  # partial (if any) is kept for a range resume
        raise ArenaStoreError(
            f"blob {digest} unfetchable after {self.policy.retry_budget} "
            f"retries from {url} (last: {last!r})"
        )

    def _download_once(
        self, url_path: str, digest: str, part: Path, blob_bytes: int
    ) -> None:
        """One transfer attempt into ``part``; raises a retryable error on
        truncation (leaving the partial for resume) or :class:`_CorruptBlob`
        on overrun."""
        part.parent.mkdir(parents=True, exist_ok=True)
        have = part.stat().st_size if part.exists() else 0
        if have > blob_bytes:
            raise _CorruptBlob(
                f"partial larger than advertised blob ({have} > {blob_bytes})"
            )
        mode = "ab"
        if have and have < blob_bytes:
            self.report.fetch_resumed += 1
        if have == blob_bytes:
            return  # complete; verification decides its fate
        with self._open(f"/blobs/{digest}", range_start=have) as resp:
            if have and resp.status == 200:
                # server ignored the Range header: restart the file
                have, mode = 0, "wb"
            with open(part, mode) as f:
                while True:
                    chunk = resp.read(self.policy.chunk_bytes)
                    if not chunk:
                        break
                    f.write(chunk)
                    have += len(chunk)
        if have < blob_bytes:
            raise EOFError(
                f"short transfer: {have}/{blob_bytes} bytes (will resume)"
            )
        if have > blob_bytes:
            raise _CorruptBlob(
                f"overlong transfer: {have}/{blob_bytes} bytes"
            )


# ---------------------------------------------------------------- the tiers
class TieredStore:
    """shm → tables/ → local store cache → remote → fallback bake.

    One instance is attached per :class:`~repro.link.workspace.Workspace`
    (``ws.attach_store``); ``ensure_arena`` is what the ``stable-remote``
    strategy calls when the baked arena is missing locally. Thread-safe:
    concurrent warmup workers asking for the same pair serialize on a
    per-pair lock, distinct pairs proceed in parallel."""

    def __init__(
        self,
        registry: Registry,
        url: Optional[str] = None,
        *,
        policy: Optional[FetchPolicy] = None,
        codec: str = "zlib",
    ):
        self.registry = registry
        self.url = url.rstrip("/") if url else None
        self.policy = policy or FetchPolicy()
        self.codec = codec
        self.report = StoreReport()
        self.cache = LocalStoreCache(store_dir(registry))
        self.client = (
            RemoteStoreClient(self.url, self.policy, self.report)
            if self.url
            else None
        )
        self._index: Optional[dict] = None
        self._index_error: Optional[ArenaStoreError] = None
        # Held across the whole index fetch: a warmup's worker threads must
        # not each pay the retry budget against a dead store — one thread
        # pays, the rest observe the memoized result (or memoized failure).
        self._index_lock = threading.Lock()
        self._lock = threading.Lock()
        self._pair_locks: dict[str, threading.Lock] = {}

    # ------------------------------------------------------------- plumbing
    def _pair_lock(self, pair: str) -> threading.Lock:
        with self._lock:
            lock = self._pair_locks.get(pair)
            if lock is None:
                lock = self._pair_locks[pair] = threading.Lock()
            return lock

    @property
    def _remote_index_path(self) -> Path:
        return store_dir(self.registry) / "remote-index.json"

    def _load_index(self) -> dict:
        """The remote's index: memoized, then the on-disk copy from a
        previous session, then the network (cached to disk on success)."""
        with self._index_lock:
            if self._index is not None:
                return self._index
            if self._index_error is not None:
                # the index already exhausted its budget this session:
                # fail fast so a dead store costs one budget per warmup,
                # not one per app (close() re-arms)
                raise self._index_error
            if self.client is not None:
                try:
                    index = self.client.fetch_index()
                    p = self._remote_index_path
                    p.parent.mkdir(parents=True, exist_ok=True)
                    tmp = p.with_suffix(f".{os.getpid()}.tmp")
                    tmp.write_text(json.dumps(index, sort_keys=True))
                    os.replace(tmp, p)
                except ArenaStoreError as e:
                    index = self._disk_index()
                    if index is None:
                        self._index_error = e
                        raise
            else:
                index = self._disk_index()
                if index is None:
                    raise ArenaStoreError(
                        "no remote URL and no cached store index "
                        f"under {store_dir(self.registry)}"
                    )
            self._index = index
            return index

    def _disk_index(self) -> Optional[dict]:
        for p in (self._remote_index_path, _index_path(self.registry)):
            if p.exists():
                try:
                    return json.loads(p.read_text())
                except (OSError, json.JSONDecodeError):
                    continue
        return None

    # ------------------------------------------------------------ main path
    def ensure_arena(self, executor, app, world, key: str) -> str:
        """Make ``tables/`` hold the baked arena for (app, key); returns
        the tier that produced it: ``"tables"``, ``"cache"``, ``"remote"``
        or ``"bake"`` (the degraded fallback)."""
        pair = pair_key(app.content_hash, key)
        with self._pair_lock(pair):
            apath = self.registry.arena_path(app.content_hash, key)
            mpath = self.registry.arena_meta_path(app.content_hash, key)
            if apath.exists() and mpath.exists():
                self.report.tables_hits += 1
                return "tables"
            try:
                entry = self._index_entry(pair, app, key)
                if entry is not None and self.cache.has_blob(entry["digest"]):
                    raw = self._verified_cached_blob(entry)
                    if raw is not None:
                        self._install(entry, raw, apath, mpath)
                        self.report.cache_hits += 1
                        return "cache"
                if entry is not None and self.client is not None:
                    raw = self.client.fetch_blob(entry, self.cache)
                    self._install(entry, raw, apath, mpath)
                    return "remote"
                raise ArenaStoreError(
                    f"pair {pair} not available from the store"
                    + ("" if entry is None else " (no remote client)")
                )
            except ArenaStoreError as e:
                self.report.errors.append(str(e))
                return self._fallback_bake(executor, app, world, key, e)

    def _index_entry(self, pair: str, app, key: str) -> Optional[dict]:
        entry = self._load_index().get("entries", {}).get(pair)
        if entry is None:
            return None
        # an index lying about whose arena this is must not install bytes
        # under the wrong key — treat like corruption, not like a miss
        if (
            entry.get("app_hash") != app.content_hash
            or entry.get("closure_hash") != key
        ):
            raise ArenaStoreError(
                f"store index entry {pair} names a different (app, closure)"
            )
        return entry

    def _verified_cached_blob(self, entry: dict) -> Optional[bytes]:
        """Re-verify a locally cached blob before every install: a corrupt
        byte on the local disk must not become epoch-visible either."""
        bpath = self.cache.blob_path(entry["digest"])
        try:
            raw = decode_bytes(bpath.read_bytes())
            if blob_digest(raw) == entry["digest"]:
                return raw
            reason = "cached blob digest mismatch"
        except CodecError as e:
            reason = f"cached blob does not decode: {e}"
        except OSError:
            return None
        self.cache.quarantine(
            bpath, digest=entry["digest"], reason=reason,
            url=str(bpath),
        )
        self.report.quarantined += 1
        return None

    def _install(self, entry: dict, raw: bytes, apath: Path, mpath: Path) -> None:
        """Atomically land verified bytes as tables/<pair>.arena(.json).

        Arena first, sidecar last: every reader treats the sidecar's
        presence as the commit point (materialize_all's reuse check,
        _build_arena_entry), so a crash between the two renames leaves a
        harmless orphan, never an adoptable half-arena."""
        sidecar = entry["sidecar"]
        if int(sidecar.get("arena_size", 0)) > len(raw):
            raise ArenaStoreError(
                f"blob {entry['digest']}: sidecar arena_size "
                f"{sidecar.get('arena_size')} exceeds blob ({len(raw)} bytes)"
            )
        pid = os.getpid()
        atmp = apath.with_name(f".{apath.name}.{pid}.fetch")
        atmp.write_bytes(raw)
        os.replace(atmp, apath)
        mtmp = mpath.with_name(f".{mpath.name}.{pid}.fetch")
        mtmp.write_text(json.dumps(sidecar, sort_keys=True))
        os.replace(mtmp, mpath)

    def _fallback_bake(self, executor, app, world, key, cause) -> str:
        if executor is None:
            raise cause
        try:
            executor.materialize(app, world, executor.manager.epoch, key=key)
        except Exception as bake_err:
            raise ArenaStoreError(
                f"store fetch failed ({cause}) and local bake failed too "
                f"({bake_err!r})"
            ) from cause
        self.report.fallback_bakes += 1
        self.report.degraded = True
        return "bake"

    # ------------------------------------------------------------ utilities
    def close(self) -> None:
        """Drop the memoized index and any memoized index failure (tests
        flip servers mid-session; a recovered store gets a fresh chance)."""
        with self._index_lock:
            self._index = None
            self._index_error = None


def reset_store_dir(registry: Registry) -> None:
    """Testing helper: wipe ``<root>/store/`` entirely."""
    shutil.rmtree(store_dir(registry), ignore_errors=True)
