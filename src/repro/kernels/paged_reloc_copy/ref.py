"""Pure-jnp oracle for the paged relocation copy."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_reloc_copy_ref(
    blob: jax.Array, arena: jax.Array, src_page: jax.Array, dst_page: jax.Array
) -> jax.Array:
    if src_page.shape[0] == 0:
        return arena
    return arena.at[dst_page].set(blob[src_page])
