"""Management-time journal: operator-visible staging, diffs, and previews.

The paper's second headline claim is *observability*: stable linking lets a
developer "accurately observe a relocation mapping" before runtime. This
module extends that observability to the management time itself — the window
between ``begin_mgmt`` and commit, which used to be a black box.

Three pieces:

* ``Journal`` — an append-only JSONL record of every staged operation
  (publish / publish-file / remove, with object hashes, sizes, timestamps),
  persisted as ``<root>/journal.jsonl`` alongside the Manager's state file.
  The Manager appends one entry per staged op and truncates the journal at
  every session boundary (commit, abort, reset, fresh begin), so the file
  always describes exactly the *current* management session. A process that
  dies mid-management leaves the journal behind;
  ``Workspace.management(resume=True)`` replays it so the operator sees what
  the dead session had staged before choosing to continue or reset.

* ``WorldDiff`` — the structural view: added / removed / upgraded bindings
  of the staged world versus the committed world (``tx.diff()``).

* ``PreviewReport`` — the semantic view: a relocation-delta preview
  (``tx.preview()``) that dry-runs resolution against the staged world and
  reports, per application, which relocations change provider/addend, which
  go unresolved, and which tables will be rebuilt at commit. Nothing is
  written: the committed world, its tables, and the epoch counter are
  untouched by a preview.

Journal writes happen only during management time; the epoch load hot path
never touches this module (see ``benchmarks/run.py --smoke``'s
``journal_epoch_overhead`` row, which asserts zero bytes of journal I/O
across the strategy sweep).

Journal file format (one JSON object per line)::

    {"seq": 1, "op": "publish", "name": "weights:olmoe", "version": "2",
     "kind": 1, "content_hash": "…", "payload_size": 4096, "ts": 1699.0}

``op`` is one of ``publish`` / ``publish-file`` / ``remove``; ``remove``
entries carry the unbound name and the hash it pointed at. ``seq`` is
1-based and strictly increasing within a session.
"""

from __future__ import annotations

import csv
import io
import json
import os
import time
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import TYPE_CHECKING, Optional

from repro.core.errors import UnknownObjectError, UnresolvedSymbolError
from repro.core.objects import RelocType
from repro.core.relocation import RelocationTable
from repro.core.resolver import DynamicResolver, dependency_closure
from repro.core.symbol_index import closure_hash

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.core.manager import Manager


# --------------------------------------------------------------------------
# The journal proper
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class JournalEntry:
    """One staged operation, as recorded in ``journal.jsonl``."""

    seq: int
    op: str                     # "publish" | "publish-file" | "remove" | "edit"
    name: str
    content_hash: str = ""      # hash bound ("" for remove of unknown)
    payload_size: int = 0
    kind: int = -1              # ObjectKind int (-1 when unknown/remove)
    version: str = ""
    ts: float = 0.0

    def to_json(self) -> dict:
        return asdict(self)

    @staticmethod
    def from_json(d: dict) -> "JournalEntry":
        return JournalEntry(
            seq=int(d["seq"]),
            op=str(d["op"]),
            name=str(d["name"]),
            content_hash=str(d.get("content_hash", "")),
            payload_size=int(d.get("payload_size", 0)),
            kind=int(d.get("kind", -1)),
            version=str(d.get("version", "")),
            ts=float(d.get("ts", 0.0)),
        )


class Journal:
    """Append-only persisted record of one management session's staged ops.

    Satisfies the Manager's journal-sink protocol (``record`` / ``clear`` /
    ``last_seq``). Appends are flushed per entry so a crash loses at most
    the op that was in flight — and that op's staging is then also absent
    from the persisted ``pending`` snapshot, so journal and state cannot
    disagree by more than the crashing op.

    **Rotation** (``rotate_bytes``): a very long management session — a
    sweep republishing the same bundles thousands of times — grows the
    journal without bound even though its *net* staging is small. Once the
    file exceeds ``rotate_bytes`` after an append, it is compacted in
    place: only the LAST entry per name survives (exactly the entries
    ``replay`` would let win), original sequence numbers are kept (so
    ``last_seq`` and the state file's ``journal_seq`` stay consistent),
    and the file as it stood before the MOST RECENT rotation is parked at
    ``<path>.1`` (one generation — an earlier rotation's archive is
    overwritten). ``management(resume=True)`` replay over a rotated journal
    reproduces the same staged world as over the unrotated one. A session
    whose net staging is genuinely larger than the threshold cannot be
    shrunk and is left alone.
    """

    def __init__(
        self, path: str | os.PathLike, *, rotate_bytes: Optional[int] = None
    ):
        self.path = Path(path)
        self.rotate_bytes = rotate_bytes
        self.rotations = 0
        # After a no-op compaction (net staging genuinely >= threshold),
        # skip re-attempts until the file grows past this — otherwise every
        # append would re-parse the whole journal just to find nothing.
        self._rotate_retry_size = 0
        self._repair_torn_tail()
        self._seq = self._scan_last_seq()

    # ----------------------------------------------------------- protocol
    @property
    def last_seq(self) -> int:
        return self._seq

    def record(
        self,
        op: str,
        *,
        name: str,
        content_hash: str = "",
        payload_size: int = 0,
        kind: int = -1,
        version: str = "",
    ) -> JournalEntry:
        self._seq += 1
        entry = JournalEntry(
            seq=self._seq,
            op=op,
            name=name,
            content_hash=content_hash,
            payload_size=payload_size,
            kind=kind,
            version=version,
            ts=time.time(),
        )
        with self.path.open("a") as f:
            f.write(json.dumps(entry.to_json(), sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
            size = f.tell()
        if (
            self.rotate_bytes is not None
            and size > self.rotate_bytes
            and size > self._rotate_retry_size
        ):
            self._rotate(size)
        return entry

    def clear(self) -> None:
        """Truncate the journal (session boundary: begin/commit/abort/reset).
        The rotation archive describes the now-dead session and goes too."""
        self._seq = 0
        self._rotate_retry_size = 0
        if self.path.exists():
            self.path.write_text("")
        if self.archive_path.exists():
            self.archive_path.unlink()

    @property
    def archive_path(self) -> Path:
        """Where the most recent rotation parks the pre-compaction history."""
        return self.path.with_name(self.path.name + ".1")

    def _rotate(self, size: int) -> None:
        """Compact the journal to its replay-equivalent minimum.

        ``replay`` is last-wins per name, so only the final entry per name
        affects the staged world it reproduces. Their original ``seq``
        values are kept (they are already strictly increasing, and the
        newest entry is by construction a survivor), which keeps
        ``last_seq`` — and therefore the resume-authority check against
        ``state.json``'s ``journal_seq`` — exactly as before rotation.

        Crash safety: the old file is parked at ``archive_path`` first and
        the compacted file lands by atomic replace. A crash in between
        leaves no active journal — resume then falls back to the persisted
        ``pending`` snapshot and resyncs the journal from it
        (``Workspace._resync_journal_from_staged``), losing nothing.
        """
        entries = self.entries()
        last: dict[str, JournalEntry] = {}
        for e in entries:
            last.pop(e.name, None)   # re-insert to keep last-occurrence order
            last[e.name] = e
        if len(last) >= len(entries):
            # nothing to reclaim: net staging really is this large. Back
            # off until the file doubles so appends stay O(1) amortized.
            self._rotate_retry_size = size * 2
            return
        os.replace(self.path, self.archive_path)
        tmp = self.path.with_name(self.path.name + ".tmp")
        with tmp.open("w") as f:
            for e in last.values():
                f.write(json.dumps(e.to_json(), sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path)
        self.rotations += 1
        self._rotate_retry_size = 0

    # ------------------------------------------------------------- reading
    def entries(self) -> list[JournalEntry]:
        if not self.path.exists():
            return []
        out = []
        for line in self.path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                out.append(JournalEntry.from_json(json.loads(line)))
            except (json.JSONDecodeError, KeyError, ValueError):
                # A crash mid-append can tear the final line; everything
                # before it is intact (appends are flushed per entry), so
                # stop there instead of making the store unopenable.
                break
        return out

    def replay(self, bindings: dict[str, str]) -> dict[str, str]:
        """Apply the journaled ops over ``bindings`` (the committed world),
        reproducing the staged world the recording session had built."""
        staged = dict(bindings)
        for e in self.entries():
            if e.op in ("publish", "publish-file"):
                staged[e.name] = e.content_hash
            elif e.op == "remove":
                staged.pop(e.name, None)
        return staged

    def _scan_last_seq(self) -> int:
        es = self.entries()
        return es[-1].seq if es else 0

    def _repair_torn_tail(self) -> None:
        """Rewrite the file to its parseable prefix when a crash tore the
        final line. Without this, the next append would merge onto the
        fragment and make BOTH entries unreadable — silently truncating
        every later op at the corrupt line."""
        if not self.path.exists():
            return
        raw = self.path.read_text()
        lines = [ln for ln in raw.splitlines() if ln.strip()]
        es = self.entries()  # parses the clean prefix only
        if len(es) == len(lines) and (not raw or raw.endswith("\n")):
            return
        with self.path.open("w") as f:
            for e in es:
                f.write(json.dumps(e.to_json(), sort_keys=True) + "\n")
            f.flush()
            os.fsync(f.fileno())


# --------------------------------------------------------------------------
# Structural diff: staged world vs committed world
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class WorldDiff:
    """Binding-level delta of a staged world against the committed world."""

    added: dict[str, str]                    # name -> new hash
    removed: dict[str, str]                  # name -> old hash
    upgraded: dict[str, tuple[str, str]]     # name -> (old hash, new hash)
    committed_world_hash: str = ""
    staged_world_hash: str = ""

    @property
    def is_empty(self) -> bool:
        return not (self.added or self.removed or self.upgraded)

    def summary(self) -> dict:
        return {
            "added": sorted(self.added),
            "removed": sorted(self.removed),
            "upgraded": sorted(self.upgraded),
            "committed_world_hash": self.committed_world_hash,
            "staged_world_hash": self.staged_world_hash,
        }

    def to_json(self) -> str:
        return json.dumps(
            {
                "added": dict(sorted(self.added.items())),
                "removed": dict(sorted(self.removed.items())),
                "upgraded": {
                    k: list(v) for k, v in sorted(self.upgraded.items())
                },
                "committed_world_hash": self.committed_world_hash,
                "staged_world_hash": self.staged_world_hash,
            },
            indent=1,
        )


def world_diff(
    committed: dict[str, str],
    staged: dict[str, str],
    *,
    committed_world_hash: str = "",
    staged_world_hash: str = "",
) -> WorldDiff:
    added = {n: h for n, h in staged.items() if n not in committed}
    removed = {n: h for n, h in committed.items() if n not in staged}
    upgraded = {
        n: (committed[n], h)
        for n, h in staged.items()
        if n in committed and committed[n] != h
    }
    return WorldDiff(
        added=added,
        removed=removed,
        upgraded=upgraded,
        committed_world_hash=committed_world_hash,
        staged_world_hash=staged_world_hash,
    )


# --------------------------------------------------------------------------
# Relocation-delta preview: dry-run materialization against the staged world
# --------------------------------------------------------------------------


@dataclass
class RelocationDelta:
    """Per-application relocation changes a commit would produce."""

    app: str
    new_app: bool = False            # app itself is newly staged
    dep_missing: Optional[str] = None  # a `needed` object absent from staged world
    changed: list[dict] = field(default_factory=list)
    unresolved: list[dict] = field(default_factory=list)
    edited: list[dict] = field(default_factory=list)  # staged interposition edits
    table_rebuilt: bool = False      # commit will (re-)materialize the table
    relocations: int = 0             # rows under the staged world

    @property
    def is_clean(self) -> bool:
        return not (self.changed or self.unresolved or self.dep_missing)

    def summary(self) -> dict:
        return {
            "app": self.app,
            "new_app": self.new_app,
            "dep_missing": self.dep_missing,
            "changed": len(self.changed),
            "unresolved": len(self.unresolved),
            "edited": len(self.edited),
            "table_rebuilt": self.table_rebuilt,
            "relocations": self.relocations,
        }


@dataclass
class PreviewReport:
    """The relocation-delta preview of one staged (uncommitted) world."""

    diff: WorldDiff
    deltas: list[RelocationDelta]
    epoch: int                       # epoch the commit would create
    committed_world_hash: str
    staged_world_hash: str

    @property
    def tables_to_rebuild(self) -> list[str]:
        """Apps whose dependency closure changed: commit re-materializes
        exactly these."""
        return [d.app for d in self.deltas if d.table_rebuilt]

    @property
    def tables_reused(self) -> list[str]:
        """Apps untouched by this staging: their closure hash — and hence
        their materialized table and baked arena — survives the commit."""
        return [d.app for d in self.deltas if not d.table_rebuilt]

    @property
    def is_clean(self) -> bool:
        """True when commit-time materialization cannot fail on resolution:
        no unresolved refs and no missing dependencies anywhere — including
        in newly staged apps. Changed providers/addends are the *point* of
        a roll, not a defect, so they do not make a preview dirty."""
        return not any(d.unresolved or d.dep_missing for d in self.deltas)

    def delta_for(self, app: str) -> Optional[RelocationDelta]:
        for d in self.deltas:
            if d.app == app:
                return d
        return None

    def summary(self) -> dict:
        return {
            "epoch": self.epoch,
            "committed_world_hash": self.committed_world_hash,
            "staged_world_hash": self.staged_world_hash,
            "world_diff": self.diff.summary(),
            "apps": [d.summary() for d in self.deltas],
            "tables_to_rebuild": self.tables_to_rebuild,
            "tables_reused": self.tables_reused,
        }

    # ------------------------------------------------------------- views
    def records(self) -> list[dict]:
        """Flat per-symbol rows (JSON/CSV-ready) across all applications."""
        out = []
        for d in self.deltas:
            for c in d.changed:
                out.append({"app": d.app, "kind": "changed", **c})
            for u in d.unresolved:
                out.append({"app": d.app, "kind": "unresolved", **u})
            for e in d.edited:
                out.append({"app": d.app, "kind": "edited", **e})
            if d.dep_missing:
                out.append(
                    {
                        "app": d.app,
                        "kind": "dep-missing",
                        "symbol": "",
                        "old_provider": d.dep_missing,
                        "new_provider": "",
                        "old_addend": 0,
                        "new_addend": 0,
                        "detail": f"needed object {d.dep_missing!r} unbound",
                    }
                )
        return out

    def to_json(self) -> str:
        return json.dumps(
            {"summary": self.summary(), "records": self.records()}, indent=1
        )

    def to_csv(self) -> str:
        fields = [
            "app", "kind", "symbol", "old_provider", "new_provider",
            "old_addend", "new_addend", "detail",
        ]
        buf = io.StringIO()
        w = csv.DictWriter(buf, fieldnames=fields, extrasaction="ignore")
        w.writeheader()
        w.writerows(self.records())
        return buf.getvalue()


def _provider_key(name: str, version: str) -> str:
    return f"{name}@{version}" if version else name


def _mapping_from_table(table: RelocationTable) -> dict[str, dict]:
    """symbol -> binding record, from a materialized table."""
    out: dict[str, dict] = {}
    rows = table.rows
    for i in range(len(rows)):
        r = rows[i]
        sym = table.name_at(r["symbol_name"])
        prov = table.object_by_uuid(int(r["provides_so_uuid"]))
        out[sym] = {
            "provider": _provider_key(prov["name"], prov["version"])
            if prov is not None
            else "",
            "provider_hash": prov["content_hash"] if prov is not None else "",
            "addend": int(r["addend"]),
            "st_value": int(r["st_value"]),
            "type": int(r["type"]),
        }
    return out


def tolerant_resolve(app, world):
    """Dry-run resolution that never raises: a preview must report problems,
    not die on the first one.

    Returns ``(relocations, unresolved, dep_missing)`` — the bindable
    relocations, record dicts for strong refs without a provider, and the
    name of a missing ``needed`` object (whole-closure failure) if any.
    """
    try:
        scope = dependency_closure(app, world)
    except UnknownObjectError as e:
        return [], [], str(e)
    resolver = DynamicResolver(world, on_mismatch="skip")
    relocations = []
    unresolved: list[dict] = []
    for obj in scope:
        for ref in obj.refs:
            try:
                relocations.append(resolver.resolve_ref(ref, obj, scope))
            except UnresolvedSymbolError:
                unresolved.append(
                    {
                        "symbol": ref.name,
                        "old_provider": "",
                        "new_provider": "",
                        "old_addend": 0,
                        "new_addend": 0,
                        "detail": f"strong ref of {obj.name} has no provider",
                    }
                )
    return relocations, unresolved, None


def _binding_records(relocations) -> dict[str, dict]:
    """symbol -> binding record, from resolved relocations."""
    mapping: dict[str, dict] = {}
    for r in relocations:
        mapping[r.ref.name] = {
            "provider": _provider_key(r.provider.name, r.provider.version)
            if r.provider is not None
            else "",
            "provider_hash": r.provider.content_hash
            if r.provider is not None
            else "",
            "addend": int(r.addend),
            "st_value": int(r.st_value),
            "type": int(r.rtype),
        }
    return mapping


def _mapping_from_world(app, world) -> tuple[dict[str, dict], list[dict], Optional[str]]:
    """Tolerant dry-run resolution as a symbol -> binding-record mapping."""
    relocations, unresolved, dep_missing = tolerant_resolve(app, world)
    return _binding_records(relocations), unresolved, dep_missing


def app_relocation_delta(manager: "Manager", app) -> tuple[RelocationDelta, list]:
    """One application's relocation delta (staged vs committed), plus the
    staged-world relocations the dry run produced (reusable for a preview
    table, sparing callers a second resolution pass)."""
    registry = manager.registry
    committed = manager.committed_world()
    staged = manager.world()
    delta = RelocationDelta(app=app.name)
    # Tables are keyed by (app hash, closure hash): commit re-materializes
    # exactly the apps whose dependency closure changed. A broken staged
    # closure (missing dep) has no reusable table by definition.
    try:
        staged_key = closure_hash(app, staged)
        delta.table_rebuilt = not registry.table_path(
            app.content_hash, staged_key
        ).exists()
    except UnknownObjectError:
        delta.table_rebuilt = True
    # old mapping: what the committed epoch binds (table if materialized).
    # An *upgraded* app (same name, new content hash) is not new — its old
    # mapping comes from the committed version of the app object, so the
    # preview shows exactly what the app roll changes.
    committed_app = committed.get(app.name) if app.name in committed else None
    if committed_app is not None:
        try:
            committed_key = closure_hash(committed_app, committed)
        except UnknownObjectError:
            committed_key = committed.world_hash
        table_path = registry.table_path(
            committed_app.content_hash, committed_key
        )
        if not table_path.exists():
            # pre-closure-hash stores keyed tables by the world hash
            table_path = registry.table_path(
                committed_app.content_hash, committed.world_hash
            )
        if table_path.exists():
            old = _mapping_from_table(RelocationTable.load(table_path))
            old_unres: list[dict] = []
        else:
            old, old_unres, _ = _mapping_from_world(committed_app, committed)
    else:
        delta.new_app = True
        old, old_unres = {}, []
    relocations, new_unres, dep_missing = tolerant_resolve(app, staged)
    new = _binding_records(relocations)
    delta.dep_missing = dep_missing
    delta.relocations = len(new)
    old_unres_syms = {u["symbol"] for u in old_unres}
    # newly-unresolved only: refs broken by this staging, not pre-existing
    delta.unresolved = [
        u for u in new_unres if u["symbol"] not in old_unres_syms
    ]
    if not delta.new_app:
        for sym, nb in new.items():
            ob = old.get(sym)
            if ob is None:
                continue  # previously unresolved; not a provider change
            if (
                ob["provider_hash"] != nb["provider_hash"]
                or ob["addend"] != nb["addend"]
                or ob["st_value"] != nb["st_value"]
                or ob["type"] != nb["type"]
            ):
                delta.changed.append(
                    {
                        "symbol": sym,
                        "old_provider": ob["provider"],
                        "new_provider": nb["provider"],
                        "old_addend": ob["addend"],
                        "new_addend": nb["addend"],
                        "detail": (
                            "type "
                            f"{RelocType(ob['type']).name}->"
                            f"{RelocType(nb['type']).name}"
                            if ob["type"] != nb["type"]
                            else ""
                        ),
                    }
                )
        for sym, ob in old.items():
            if sym not in new and not any(
                u["symbol"] == sym for u in delta.unresolved
            ):
                # ref disappeared with a dep (e.g. provider removed and
                # the requiring object gone): surface as unresolved-ish
                delta.unresolved.append(
                    {
                        "symbol": sym,
                        "old_provider": ob["provider"],
                        "new_provider": "",
                        "old_addend": ob["addend"],
                        "new_addend": 0,
                        "detail": "binding vanished from staged world",
                    }
                )
    # Staged interposition edits (tx.rebind / Manager.stage_edit): preview
    # the rows the commit-time `interpose.rebind` will retarget, matched by
    # the same glob semantics it uses — so the operator sees the edit's
    # blast radius before any table is touched. These rows will carry
    # FLAG_EDITED in the recompiled table.
    staged_edits = [
        e for e in getattr(manager, "staged_edits", []) if e["app"] == app.name
    ]
    if staged_edits:
        from repro.core.interpose import _match_glob

        for e in staged_edits:
            prov = staged.get(e["provider"])
            prov_key = (
                _provider_key(prov.name, prov.version) if prov else e["provider"]
            )
            seen: set[tuple[str, str]] = set()
            for r in relocations:
                sym = r.ref.name
                if not _match_glob(sym, e["symbol_glob"]):
                    continue
                rg = e.get("requires_glob")
                if rg and not _match_glob(r.requirer.name, rg):
                    continue
                if (sym, r.requirer.name) in seen:
                    continue
                seen.add((sym, r.requirer.name))
                delta.edited.append(
                    {
                        "symbol": sym,
                        "old_provider": _provider_key(
                            r.provider.name, r.provider.version
                        )
                        if r.provider is not None
                        else "",
                        "new_provider": prov_key,
                        "old_addend": int(r.addend),
                        "new_addend": int(r.addend),
                        "detail": (
                            f"staged edit {e['symbol_glob']!r}"
                            + (f" requires={rg!r}" if rg else "")
                            + f" in {r.requirer.name}"
                        ),
                    }
                )
    return delta, relocations


def preview_world(manager: "Manager") -> PreviewReport:
    """Dry-run the staged world and report the per-app relocation delta.

    Reads the committed table when one exists (the mapping the running epoch
    actually uses); resolves dynamically otherwise. Never writes: tables are
    only (re-)materialized by the real commit.
    """
    committed = manager.committed_world()
    staged = manager.world()
    diff = world_diff(
        manager.committed_bindings,
        manager.staged_bindings,
        committed_world_hash=committed.world_hash,
        staged_world_hash=staged.world_hash,
    )
    deltas = [
        app_relocation_delta(manager, app)[0]
        for app in staged.applications()
    ]
    return PreviewReport(
        diff=diff,
        deltas=deltas,
        epoch=manager.epoch + 1,
        committed_world_hash=committed.world_hash,
        staged_world_hash=staged.world_hash,
    )
