"""Pallas TPU kernels for the perf-critical hot spots.

    paged_reloc_copy — the paper's relocation-table walk as a scalar-
                       prefetched paged HBM gather (the stable-linking
                       epoch loader's TPU form)
    flash_attention  — blockwise online-softmax attention (causal / GQA /
                       sliding window) for train + prefill
    rmsnorm          — fused norm

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
ref.py (pure-jnp oracle). Validated on CPU with interpret=True; compiled
via Mosaic on TPU.
"""

from . import flash_attention, paged_reloc_copy, rmsnorm

__all__ = ["flash_attention", "paged_reloc_copy", "rmsnorm"]
