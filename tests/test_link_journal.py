"""Management-time journal: staged-op persistence, operator-visible diffs,
relocation-delta previews, and the crash-recovery matrix."""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro.core import (
    Mode,
    ModeError,
    ObjectKind,
    StateSchemaError,
    SymbolRef,
    make_object,
)
from repro.core.registry import STATE_SCHEMA, Registry
from repro.link import Workspace

from conftest import build_app, build_bundle


class OperatorAbort(Exception):
    """Raised by test bodies to roll a transaction back on purpose."""


def _publish_base(ws):
    tensors = {
        "s/a": np.full(8, 1.0, np.float32),
        "s/b": np.arange(6, dtype=np.float32).reshape(2, 3),
    }
    bundle = build_bundle("w", tensors, version="1")
    app = build_app(
        "app",
        [
            SymbolRef("s/a", (8,), "float32"),
            SymbolRef("s/b", (2, 3), "float32"),
        ],
        ["w"],
    )
    with ws.management() as tx:
        tx.publish(*bundle)
        tx.publish(app)
    return tensors


# --------------------------------------------------------------- journaling
def test_journal_records_every_staged_op(workspace):
    ws = workspace
    _publish_base(ws)
    with ws.management() as tx:
        b2 = build_bundle("w2", {"s/c": np.zeros(4, np.float32)})
        tx.publish(*b2)
        tx.remove("w2")
        entries = tx.journal_entries()
        assert [e.op for e in entries] == ["publish", "remove"]
        assert entries[0].name == "w2"
        assert entries[0].content_hash == b2[0].content_hash
        assert entries[0].payload_size == b2[0].payload_size
        assert entries[0].seq == 1 and entries[1].seq == 2
        assert entries[1].content_hash == b2[0].content_hash
        assert all(e.ts > 0 for e in entries)
    # session boundary (commit) truncates the journal
    assert ws.journal.entries() == []


def test_journal_file_lives_beside_state(workspace):
    ws = workspace
    with ws.management() as tx:
        tx.publish(*build_bundle("w", {"s/a": np.ones(4, np.float32)}))
        assert ws.registry.journal_path.exists()
        raw = ws.registry.journal_path.read_text().strip().splitlines()
        assert len(raw) == 1
        rec = json.loads(raw[0])
        assert rec["op"] == "publish" and rec["name"] == "w"


def test_journal_cleared_on_abort(workspace):
    ws = workspace
    _publish_base(ws)
    with pytest.raises(RuntimeError):
        with ws.management() as tx:
            tx.publish(*build_bundle("w2", {"s/c": np.zeros(4, np.float32)}))
            raise RuntimeError()
    assert ws.journal.entries() == []


# --------------------------------------------------------------------- diff
def test_tx_diff_reports_added_removed_upgraded(workspace):
    ws = workspace
    _publish_base(ws)
    with pytest.raises(OperatorAbort):
        with ws.management() as tx:
            assert tx.diff().is_empty
            w_v2 = build_bundle(
                "w",
                {"s/a": np.full(8, 2.0, np.float32),
                 "s/b": np.zeros((2, 3), np.float32)},
                version="2",
            )
            new = build_bundle("extra", {"s/x": np.ones(2, np.float32)})
            tx.publish(*w_v2)
            tx.publish(*new)
            tx.remove("app")
            d = tx.diff()
            assert set(d.added) == {"extra"}
            assert set(d.removed) == {"app"}
            assert set(d.upgraded) == {"w"}
            old_hash, new_hash = d.upgraded["w"]
            assert new_hash == w_v2[0].content_hash and old_hash != new_hash
            assert d.staged_world_hash != d.committed_world_hash
            js = json.loads(d.to_json())
            assert js["added"] == {"extra": new[0].content_hash}
            raise OperatorAbort("do not commit this mess")
    assert ws.mode == Mode.EPOCH
    assert "app" in ws.world() and "extra" not in ws.world()


# ------------------------------------------------------------------ preview
def test_preview_reports_relocation_delta_on_upgrade(workspace):
    """A staged library upgrade reports the exact per-app delta *before*
    commit: changed providers, new unresolved refs, tables-to-rebuild."""
    from repro.core import SymbolRef

    ws = workspace
    _publish_base(ws)
    epoch = ws.epoch
    with pytest.raises(OperatorAbort):
        with ws.management() as tx:
            # v2 drops s/b (app's strong ref goes unresolved), keeps s/a
            w_v2 = build_bundle(
                "w", {"s/a": np.full(8, 2.0, np.float32)}, version="2"
            )
            tx.publish(*w_v2)
            p = tx.preview()
            assert set(p.diff.upgraded) == {"w"}
            d = p.delta_for("app")
            assert d is not None and not d.new_app
            changed_syms = {c["symbol"] for c in d.changed}
            assert "s/a" in changed_syms      # provider hash changed (v1->v2)
            sa = next(c for c in d.changed if c["symbol"] == "s/a")
            assert sa["old_provider"] == "w@1" and sa["new_provider"] == "w@2"
            assert [u["symbol"] for u in d.unresolved] == ["s/b"]
            assert d.table_rebuilt
            assert p.tables_to_rebuild == ["app"]
            assert not p.is_clean
            # JSON / CSV views
            js = json.loads(p.to_json())
            kinds = {r["kind"] for r in js["records"]}
            assert kinds == {"changed", "unresolved"}
            csv_text = p.to_csv()
            assert "s/b" in csv_text and "unresolved" in csv_text
            raise OperatorAbort("operator aborts the bad roll")
    # rollback happened; the preview never wrote anything
    assert ws.epoch == epoch
    np.testing.assert_array_equal(
        ws.load("app")["s/a"], np.full(8, 1.0, np.float32)
    )


def test_preview_clean_upgrade_and_sqlite_view(workspace):
    from repro.core import inspector

    ws = workspace
    _publish_base(ws)
    with ws.management() as tx:
        w_v2 = build_bundle(
            "w",
            {"s/a": np.full(8, 3.0, np.float32),
             "s/b": np.ones((2, 3), np.float32)},
            version="2",
        )
        tx.publish(*w_v2)
        p = tx.preview()
        d = p.delta_for("app")
        assert d.unresolved == []
        assert {c["symbol"] for c in d.changed} == {"s/a", "s/b"}
        assert d.relocations == 2
        conn = inspector.preview_to_sqlite(p)
        n = conn.execute(
            "SELECT COUNT(*) FROM pending_changes WHERE kind='changed'"
        ).fetchone()[0]
        assert n == 2
    # commit happened; the preview matched what materialization now did
    img = ws.load("app")
    np.testing.assert_array_equal(img["s/a"], np.full(8, 3.0, np.float32))


def test_preview_new_app_and_addend_change(workspace):
    from repro.core import SymbolRef

    ws = workspace
    stacked = np.arange(32, dtype=np.float32).reshape(4, 8)
    with ws.management() as tx:
        tx.publish(*build_bundle("lib", {"x": stacked}))
        tx.publish(
            build_app("app", [SymbolRef("x[1]", (8,), "float32")], ["lib"])
        )
    with pytest.raises(OperatorAbort):
        with ws.management() as tx:
            # re-stack: x grows a row in front, so x[1] keeps shape but the
            # provider content (hence hash) changes
            restacked = np.concatenate(
                [np.zeros((1, 8), np.float32), stacked]
            )
            tx.publish(*build_bundle("lib", {"x": restacked}, version="2"))
            app2 = build_app(
                "app2", [SymbolRef("x[2]", (8,), "float32")], ["lib"]
            )
            tx.publish(app2)
            p = tx.preview()
            d2 = p.delta_for("app2")
            assert d2.new_app and d2.table_rebuilt
            d1 = p.delta_for("app")
            assert {c["symbol"] for c in d1.changed} == {"x[1]"}
            raise OperatorAbort("preview only")


def test_preview_upgraded_app_is_not_treated_as_new(workspace):
    """Staging a new version of an application itself must preview against
    the committed version's mapping — an app roll is exactly what the
    preview exists to expose, not a 'new app' with an empty delta."""
    ws = workspace
    _publish_base(ws)
    with pytest.raises(OperatorAbort):
        with ws.management() as tx:
            # app v2 drops its s/b ref
            app_v2, _ = make_object(
                name="app", version="2", kind=ObjectKind.APPLICATION,
                refs=[SymbolRef("s/a", (8,), "float32")],
                needed=("w",),
            )
            tx.publish(app_v2)
            d = tx.preview().delta_for("app")
            assert not d.new_app            # upgraded, not new
            assert d.changed == []          # s/a still binds w@1 unchanged
            vanished = [u for u in d.unresolved if u["symbol"] == "s/b"]
            assert len(vanished) == 1
            assert vanished[0]["detail"] == "binding vanished from staged world"
            raise OperatorAbort("preview only")


def test_journal_append_after_torn_tail_repairs_file(tmp_path):
    """A torn trailing line must be dropped on reopen BEFORE the next
    append — otherwise fragment+entry merge into one unparseable line and
    every later op silently disappears from replay."""
    from repro.link import Journal

    p = tmp_path / "journal.jsonl"
    j = Journal(p)
    j.record("publish", name="a", content_hash="h1")
    j.record("publish", name="b", content_hash="h2")
    with p.open("a") as f:
        f.write('{"seq": 3, "op": "pub')  # torn mid-write, no newline
    j2 = Journal(p)  # reopen repairs the tail
    assert [e.name for e in j2.entries()] == ["a", "b"]
    assert j2.last_seq == 2
    j2.record("publish", name="c", content_hash="h3")
    entries = Journal(p).entries()  # fully parseable from a fresh reader
    assert [e.name for e in entries] == ["a", "b", "c"]
    assert entries[-1].seq == 3


def test_explain_pending_previews_staged_world(workspace):
    ws = workspace
    _publish_base(ws)
    with pytest.raises(ModeError):
        ws.explain("app", pending=True)  # no staged world during an epoch
    with pytest.raises(OperatorAbort):
        with ws.management() as tx:
            tx.publish(
                *build_bundle("w", {"s/a": np.full(8, 5.0, np.float32)},
                              version="2")
            )
            rep = ws.explain("app", pending=True)
            assert rep.pending and rep.source == "staged-preview"
            assert rep.delta is not None
            assert [u["symbol"] for u in rep.delta.unresolved] == ["s/b"]
            assert rep.summary()["pending_delta"]["unresolved"] == 1
            # tolerant: the broken staged world still explains (s/a bound)
            assert rep.relocations == 1
            raise OperatorAbort("abort the roll")
    rep = ws.explain("app")
    assert not rep.pending and rep.source == "materialized-table"


# ------------------------------------------------------- crash recovery
def _crash_mid_management(tmp_path, n_ops=3):
    """Simulate a session that staged n ops and died before commit."""
    ws = Workspace.open(tmp_path / "store")
    _publish_base(ws)
    ws.manager.begin_mgmt()
    staged_hashes = {}
    for i in range(n_ops - 1):
        b, p = build_bundle(f"lib{i}", {"t": np.full(4, float(i), np.float32)})
        ws.manager.update_obj(b, p)
        staged_hashes[f"lib{i}"] = b.content_hash
    ws.manager.remove_obj("app")
    del ws  # process "dies": no commit, no abort
    return staged_hashes


def test_resume_replays_journal_and_diff_matches(tmp_path):
    staged = _crash_mid_management(tmp_path, n_ops=3)
    ws2 = Workspace.open(tmp_path / "store")  # new process, same store
    assert ws2.mode == Mode.MANAGEMENT       # crashed state is visible
    with pytest.raises(OperatorAbort):
        with ws2.management(resume=True) as tx:
            assert tx.resumed
            entries = tx.journal_entries()
            assert [e.op for e in entries] == ["publish", "publish", "remove"]
            d = tx.diff()
            assert d.added == staged
            assert set(d.removed) == {"app"}
            assert d.upgraded == {}
            raise OperatorAbort("inspected the corpse; resets instead")
    # rollback returned to the committed epoch
    assert ws2.mode == Mode.EPOCH
    assert "app" in ws2.world()


def test_resume_then_commit_finishes_the_crashed_roll(tmp_path):
    staged = _crash_mid_management(tmp_path, n_ops=2)
    ws2 = Workspace.open(tmp_path / "store")
    with ws2.management(resume=True) as tx:
        assert set(tx.diff().added) == set(staged)
    assert ws2.mode == Mode.EPOCH and ws2.epoch == 2
    assert "lib0" in ws2.world() and "app" not in ws2.world()


def test_no_resume_resets_staged_and_truncates_journal(tmp_path):
    _crash_mid_management(tmp_path, n_ops=3)
    ws2 = Workspace.open(tmp_path / "store")
    assert len(ws2.journal.entries()) == 3
    with ws2.management() as tx:  # resume=False: start clean
        assert not tx.resumed
        assert tx.diff().is_empty
        assert tx.journal_entries() == []
    assert ws2.journal.entries() == []
    assert "app" in ws2.world()  # the crashed removal did not land


def test_resume_heals_pending_snapshot_from_journal(tmp_path):
    """The journal is authoritative on resume: a pending snapshot that lost
    an op (state write raced the crash) is rebuilt by replay."""
    _crash_mid_management(tmp_path, n_ops=3)
    reg = Registry(tmp_path / "store")
    state = json.loads(reg.state_path.read_text())
    state["pending"] = dict(state["world"])  # pending lost all staged ops
    reg.state_path.write_text(json.dumps(state))
    ws2 = Workspace.open(tmp_path / "store")
    with pytest.raises(OperatorAbort):
        with ws2.management(resume=True) as tx:
            d = tx.diff()
            assert set(d.added) == {"lib0", "lib1"}
            assert set(d.removed) == {"app"}
            raise OperatorAbort("inspect only")


def test_preview_is_clean_not_masked_by_new_app(workspace):
    """A newly staged app with unresolved strong refs must make the preview
    dirty — commit-time materialization would fail on it."""
    ws = workspace
    _publish_base(ws)
    with pytest.raises(OperatorAbort):
        with ws.management() as tx:
            tx.publish(
                build_app(
                    "newapp",
                    [SymbolRef("missing/sym", (4,), "float32")],
                    ["w"],
                )
            )
            p = tx.preview()
            d = p.delta_for("newapp")
            assert d.new_app
            assert [u["symbol"] for u in d.unresolved] == ["missing/sym"]
            assert not p.is_clean
            raise OperatorAbort("preview said no")


def test_torn_trailing_journal_line_does_not_brick_the_store(tmp_path):
    """A crash can tear the final journal line mid-append; the store must
    still open and resume from the intact prefix."""
    _crash_mid_management(tmp_path, n_ops=3)
    reg = Registry(tmp_path / "store")
    with reg.journal_path.open("a") as f:
        f.write('{"seq": 4, "op": "pub')  # torn mid-write
    ws2 = Workspace.open(tmp_path / "store")  # must not raise
    assert len(ws2.journal.entries()) == 3    # intact prefix only
    with pytest.raises(OperatorAbort):
        with ws2.management(resume=True) as tx:
            assert set(tx.diff().added) == {"lib0", "lib1"}
            raise OperatorAbort("inspect only")


def test_stale_journal_behind_state_is_not_replayed(tmp_path):
    """A journal that lost entries relative to state.json (swapped or
    truncated out-of-band) must not be replayed over the newer pending
    snapshot — the snapshot wins, and the journal is resynced to it."""
    _crash_mid_management(tmp_path, n_ops=3)
    reg = Registry(tmp_path / "store")
    # drop the journal's last two entries; state.json still says seq 3
    lines = reg.journal_path.read_text().strip().splitlines()
    reg.journal_path.write_text(lines[0] + "\n")
    assert json.loads(reg.state_path.read_text())["journal_seq"] == 3
    ws2 = Workspace.open(tmp_path / "store")
    with pytest.raises(OperatorAbort):
        with ws2.management(resume=True) as tx:
            assert tx.resumed  # snapshot adopted (journal not replayed)
            d = tx.diff()
            # full staged state from the pending snapshot, not the 1-entry
            # journal prefix
            assert set(d.added) == {"lib0", "lib1"}
            assert set(d.removed) == {"app"}
            # the journal was resynced to describe the adopted staging
            ops = {(e.op, e.name) for e in tx.journal_entries()}
            assert ops == {
                ("publish", "lib0"), ("publish", "lib1"), ("remove", "app"),
            }
            raise OperatorAbort("inspect only")


def test_resync_survives_crash_after_adoption(tmp_path):
    """Regression: staging adopted from the pending snapshot (journal did
    not describe it) must survive a later op + crash + second resume —
    without resync, the second replay would silently drop the adopted ops."""
    _crash_mid_management(tmp_path, n_ops=3)   # staged: +lib0 +lib1 -app
    reg = Registry(tmp_path / "store")
    reg.journal_path.unlink()                  # journal lost entirely
    ws2 = Workspace.open(tmp_path / "store")
    # adopt the snapshot via resume, stage one more op, then "die": the
    # context is held open (never exited) while a second process reads the
    # store — exactly what a crashed session leaves on disk
    ctx = ws2.management(resume=True)
    tx = ctx.__enter__()
    assert tx.resumed
    b, p = build_bundle("lib9", {"t": np.full(2, 9.0, np.float32)})
    tx.publish(b, p)

    ws3 = Workspace.open(tmp_path / "store")
    with pytest.raises(OperatorAbort):
        with ws3.management(resume=True) as tx:
            d = tx.diff()
            # adopted ops AND the post-adoption op all survive the replay
            assert set(d.added) == {"lib0", "lib1", "lib9"}
            assert set(d.removed) == {"app"}
            raise OperatorAbort("inspect only")


def test_abort_mgmt_at_epoch_zero_keeps_manager_usable(workspace):
    ws = workspace
    with pytest.raises(RuntimeError):
        with ws.management() as tx:
            tx.publish(*build_bundle("w", {"s/a": np.ones(4, np.float32)}))
            raise RuntimeError()
    assert ws.epoch == 0 and ws.mode == Mode.MANAGEMENT
    assert ws.journal.entries() == []
    # the manager is not wedged: a fresh session can stage and commit
    from repro.core import SymbolRef

    with ws.management() as tx:
        tx.publish(*build_bundle("w", {"s/a": np.ones(4, np.float32)}))
        tx.publish(build_app("app", [SymbolRef("s/a", (4,), "float32")], ["w"]))
    assert ws.epoch == 1 and ws.mode == Mode.EPOCH
    np.testing.assert_array_equal(
        ws.load("app")["s/a"], np.ones(4, np.float32)
    )


# ------------------------------------------------------- state schema
def test_state_schema_v1_migrates_in_place(tmp_path):
    ws = Workspace.open(tmp_path / "store")
    _publish_base(ws)
    state = json.loads(ws.registry.state_path.read_text())
    assert state["schema"] == STATE_SCHEMA
    # strip the v2 fields: a store written by a pre-journal build
    for k in ("schema", "journal_seq"):
        state.pop(k)
    ws.registry.state_path.write_text(json.dumps(state))
    ws2 = Workspace.open(tmp_path / "store")
    assert ws2.epoch == 1 and ws2.mode == Mode.EPOCH
    assert "app" in ws2.world()
    with ws2.management() as tx:
        tx.publish(*build_bundle("w2", {"s/c": np.zeros(2, np.float32)}))
    assert json.loads(ws2.registry.state_path.read_text())["schema"] == STATE_SCHEMA


def test_state_schema_from_the_future_refuses(tmp_path):
    ws = Workspace.open(tmp_path / "store")
    _publish_base(ws)
    state = json.loads(ws.registry.state_path.read_text())
    state["schema"] = STATE_SCHEMA + 1
    ws.registry.state_path.write_text(json.dumps(state))
    with pytest.raises(StateSchemaError):
        Workspace.open(tmp_path / "store")


# ----------------------------------------------------------- rotation
def test_journal_rotation_compacts_to_replay_equivalent(tmp_path):
    """Past the size threshold the journal compacts to the last entry per
    name — replay (last-wins) reproduces exactly the same staged world,
    sequence numbers survive, and the full history is parked at `.1`."""
    from repro.link import Journal

    p = tmp_path / "journal.jsonl"
    j = Journal(p, rotate_bytes=2048)
    for i in range(50):
        j.record("publish", name="a", content_hash=f"ha{i}")
        j.record("publish", name="b", content_hash=f"hb{i}")
    j.record("remove", name="b", content_hash="hb49")
    assert j.rotations >= 1
    assert p.stat().st_size <= 2048 + 512      # bounded despite 101 appends
    assert j.archive_path.exists()
    entries = j.entries()
    # compacted prefix + post-rotation tail: far fewer than 101 appends
    assert len(entries) < 20
    assert entries[-1].seq == j.last_seq == 101
    replayed = j.replay({"base": "h0"})
    assert replayed == {"base": "h0", "a": "ha49"}  # b removed, a last-wins


def test_journal_rotation_noop_when_net_staging_is_large(tmp_path):
    from repro.link import Journal

    p = tmp_path / "journal.jsonl"
    j = Journal(p, rotate_bytes=64)            # every append exceeds this
    for i in range(5):
        j.record("publish", name=f"n{i}", content_hash=f"h{i}")
    # all names distinct: nothing to compact, file left alone
    assert j.rotations == 0
    assert len(j.entries()) == 5


def test_resume_after_rotation_replays_net_staging(tmp_path):
    """A crashed session whose journal rotated must resume to exactly the
    staged world the dead session had built."""
    ws = Workspace.open(tmp_path / "store", journal_rotate_bytes=1024)
    _publish_base(ws)
    ws.manager.begin_mgmt()
    final = None
    for i in range(40):                        # same name over and over
        b, pay = build_bundle("lib", {"t": np.full(4, float(i), np.float32)},
                              version=str(i))
        ws.manager.update_obj(b, pay)
        final = b.content_hash
    assert ws.journal.rotations >= 1
    del ws                                     # process "dies" mid-session

    ws2 = Workspace.open(tmp_path / "store", journal_rotate_bytes=1024)
    assert ws2.mode == Mode.MANAGEMENT
    with ws2.management(resume=True) as tx:
        assert tx.resumed
        assert tx.diff().added == {"lib": final}
    assert ws2.world().resolve("lib").content_hash == final
    # session boundary clears both the journal and its rotation archive
    assert ws2.journal.entries() == []
    assert not ws2.journal.archive_path.exists()


def test_rotation_crash_between_archive_and_rewrite_recovers(tmp_path):
    """If the process dies after parking the old journal but before the
    compacted file lands, resume falls back to the persisted pending
    snapshot and resyncs the journal from it — nothing is lost."""
    ws = Workspace.open(tmp_path / "store")
    _publish_base(ws)
    ws.manager.begin_mgmt()
    b, pay = build_bundle("lib", {"t": np.ones(4, np.float32)})
    ws.manager.update_obj(b, pay)
    # simulate the crash window: journal parked, compacted file never wrote
    import os
    os.replace(ws.journal.path, ws.journal.archive_path)
    del ws

    ws2 = Workspace.open(tmp_path / "store")
    with ws2.management(resume=True) as tx:
        assert tx.resumed                      # resynced from the snapshot
        assert tx.diff().added == {"lib": b.content_hash}
    assert "lib" in ws2.world()


# ------------------------------------------------- rollback x journal replay
def test_resume_after_rollback_does_not_resurrect_aborted_generation(tmp_path):
    """``rollback_epoch`` clears the journal before recording its marker,
    so ``management(resume=True)`` over a rolled-back world replays
    NOTHING from the aborted generation — its ops are gone, not lurking in
    a journal that a later resume would re-stage."""
    ws = Workspace.open(tmp_path / "store")
    _publish_base(ws)
    v1_hash = ws.world().bindings["w"]

    # generation N+1: the roll that will turn out to be bad
    with ws.management() as tx:
        b2 = build_bundle(
            "w",
            {
                "s/a": np.full(8, 9.0, np.float32),
                "s/b": np.full((2, 3), 9.0, np.float32),
            },
            version="2",
        )
        tx.publish(*b2)
    assert ws.world().bindings["w"] == b2[0].content_hash

    bad_gen = ws.epoch_gen
    new_gen = ws.rollback_epoch()
    assert new_gen > bad_gen
    assert ws.world().bindings["w"] == v1_hash        # rolled back, byte-for-byte

    # the journal carries only the rollback marker, and replay over the
    # committed world is a no-op (replay applies publish/remove, never
    # rollback rows)
    ops = [e.op for e in ws.journal.entries()]
    assert ops == ["rollback"]
    replayed = ws.journal.replay(dict(ws.manager.committed_bindings))
    assert replayed == dict(ws.manager.committed_bindings)

    # a fresh session resuming over the rolled-back store stages nothing
    ws2 = Workspace.open(tmp_path / "store")
    assert ws2.mode == Mode.EPOCH
    with ws2.management(resume=True) as tx:
        assert not tx.resumed                # nothing crashed: clean entry
        assert tx.diff().is_empty            # v2 did NOT come back
    assert ws2.world().bindings["w"] == v1_hash
    # the next clean commit supersedes the rollback marker entirely
    assert ws2.manager.rolled_back_from == 0


def test_rollback_refused_inside_management(tmp_path):
    """Mid-transaction state is exactly what rollback must never touch:
    it targets committed generations only."""
    from repro.core.errors import RollbackError

    ws = Workspace.open(tmp_path / "store")
    _publish_base(ws)
    with ws.management() as tx:
        b2 = build_bundle("w2", {"t": np.ones(2, np.float32)})
        tx.publish(*b2)
        with pytest.raises(ModeError):
            ws.rollback_epoch()
    # and with no retained generation there is nothing to roll back to:
    # the first commit's outgoing world was empty, so the chain is empty
    ws_first = Workspace.open(tmp_path / "fresh")
    _publish_base(ws_first)
    assert ws_first.manager.retained_generations() == []
    with pytest.raises(RollbackError):
        ws_first.rollback_epoch()
