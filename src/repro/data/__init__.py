from .pipeline import Prefetcher, SyntheticTokens, make_batch

__all__ = ["Prefetcher", "SyntheticTokens", "make_batch"]
