"""LinkReport: one observable view of an application's relocation mapping.

``Workspace.explain(name)`` unifies what used to need three hand-wired
pieces (Executor stats, the raw ``RelocationTable``, and the ``inspector``
exporters) into a single mid-epoch-safe report object:

* summary numbers — epoch, world hash, relocation counts by type, provider
  breakdown, arena size;
* the last observed ``LoadStats`` for the app (if the workspace loaded it);
* the inspector's JSON / CSV / SQLite views of the full mapping.

Explaining never mutates anything and never reads payload bytes: during an
epoch it reads the materialized table; during management time (no committed
table for the staged world yet) it runs the dynamic resolver to show the
mapping the *next* epoch would materialize.
"""

from __future__ import annotations

import sqlite3
from dataclasses import dataclass, field
from typing import Iterable, Optional

from repro.core import inspector
from repro.core.executor import LoadStats
from repro.core.objects import RelocType, StoreObject
from repro.core.relocation import RelocationTable

_TYPE_NAMES = {int(t): t.name for t in RelocType}


@dataclass
class LinkReport:
    """The relocation mapping of one application under one world."""

    app: str
    epoch: int
    world_hash: str
    mode: str                      # manager mode when the report was taken
    source: str                    # "materialized-table" | "dynamic-resolution"
    relocations: int
    arena_bytes: int
    by_type: dict[str, int] = field(default_factory=dict)
    providers: dict[str, int] = field(default_factory=dict)
    stats: Optional[LoadStats] = None   # last observed load, if any
    table: RelocationTable = None       # the full mapping (not in summary())
    # pre-commit only (explain(pending=True)): the app's relocation delta
    # versus the committed epoch — a repro.link.journal.RelocationDelta
    delta: Optional[object] = None
    # summary of the most recent end_mgmt materialization pass (which apps
    # re-materialized vs reused their tables, index/bake timings), if one
    # happened in this process — a MaterializationResult.summary() dict
    materialization: Optional[dict] = None

    @property
    def pending(self) -> bool:
        """True when this report explains a staged, uncommitted world."""
        return self.source == "staged-preview"

    # ------------------------------------------------------------ summary
    def summary(self) -> dict:
        """JSON-ready scalar view (no table, stats flattened)."""
        out = {
            "app": self.app,
            "epoch": self.epoch,
            "world_hash": self.world_hash,
            "mode": self.mode,
            "source": self.source,
            "relocations": self.relocations,
            "arena_bytes": self.arena_bytes,
            "by_type": dict(self.by_type),
            "providers": dict(self.providers),
        }
        if self.delta is not None:
            out["pending_delta"] = self.delta.summary()
        if self.materialization is not None:
            out["materialization"] = dict(self.materialization)
        if self.stats is not None:
            out["last_load"] = {
                "strategy": self.stats.strategy,
                "startup_s": self.stats.startup_s,
                "resolve_s": self.stats.resolve_s,
                "table_load_s": self.stats.table_load_s,
                "io_s": self.stats.io_s,
                "index_build_s": self.stats.index_build_s,
                "relocations": self.stats.relocations,
                "probes": self.stats.probes,
                "bytes_loaded": self.stats.bytes_loaded,
            }
        return out

    # ------------------------------------------------- inspector passthrough
    def records(self) -> list[dict]:
        """Full-string relocation rows (the paper's Figure 6 struct)."""
        return inspector.table_records(self.table)

    def to_json(self) -> str:
        return inspector.to_json(self.table)

    def to_csv(self) -> str:
        return inspector.to_csv(self.table)

    def to_sqlite(
        self,
        path: str = ":memory:",
        *,
        abi_objects: Iterable[StoreObject] = (),
    ) -> sqlite3.Connection:
        return inspector.to_sqlite(
            [self.table], abi_objects=abi_objects, path=path
        )


def report_from_table(
    table: RelocationTable,
    *,
    app: str,
    epoch: int,
    world_hash: str,
    mode: str,
    source: str,
    stats: Optional[LoadStats] = None,
    delta: Optional[object] = None,
    materialization: Optional[dict] = None,
) -> LinkReport:
    """Build the summary breakdowns from a relocation table."""
    rows = table.rows
    by_type: dict[str, int] = {}
    providers: dict[str, int] = {}
    for i in range(len(rows)):
        tname = _TYPE_NAMES[int(rows["type"][i])]
        by_type[tname] = by_type.get(tname, 0) + 1
        prov = table.object_by_uuid(int(rows["provides_so_uuid"][i]))
        pname = prov["name"] if prov is not None else "(initializer)"
        providers[pname] = providers.get(pname, 0) + 1
    return LinkReport(
        app=app,
        epoch=epoch,
        world_hash=world_hash,
        mode=mode,
        source=source,
        relocations=len(rows),
        arena_bytes=int(table.arena_size),
        by_type=by_type,
        providers=providers,
        stats=stats,
        table=table,
        delta=delta,
        materialization=materialization,
    )
