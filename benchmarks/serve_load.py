"""Serving-tier load benchmark: p50/p99 under Poisson traffic.

    PYTHONPATH=src python -m benchmarks.serve_load [--smoke]

PRs 3-5 measured how fast an epoch *loads*; this harness measures what the
loaded fleet *does*: a dispatcher drives Poisson arrivals through shm
request/response rings (``repro.serve.traffic``) into ``workers`` real
processes, each running the continuous-batching ``engine.serve_loop`` over
a ``stable-shm`` arena (one physical weight copy machine-wide). Emits the
serving numbers the roadmap's later items (blue/green rollover, remote
arena store) will be judged against:

    serve/p50_latency, serve/p99_latency   us rows (end-to-end, steady
                                           state — workers are warmed off
                                           the clock first)
    serve/req_per_s, serve/tok_per_s       derived rows (higher = better;
                                           perf_gate classifies them out
                                           of the microsecond sweep)

It also pins PR 6's satellite fix with a before/after pair on the same
engine: ``serve/generate_hostsync`` times the OLD decode loop (a blocking
``np.asarray`` per token — one host<->device round-trip per step) against
``serve/generate_devacc`` (device-side accumulation, one transfer at the
end), reported as us per decoded token.

Rows are MERGED into ``BENCH_6.json`` (``run.py --smoke`` writes the load
rows first in CI; this harness adds the serving rows), and
``perf_gate.py`` asserts the p99 row is present, nonzero, and finite.
"""

from __future__ import annotations

import sys

import numpy as np

BENCH_JSON = "BENCH_6.json"

ARCH = "mamba2-370m"          # constant-state decode: the serving workhorse


def _publish_serve_app(ws, arch: str):
    """Publish the weights bundle + app for ``arch`` (smoke config)."""
    from repro import models
    from repro.ckpt import bundle_from_params
    from repro.configs import get_config
    from repro.core import ObjectKind, make_object

    cfg = get_config(arch, smoke=True)
    params = {
        n: np.asarray(v) for n, v in models.init_params(cfg, 0).items()
    }
    bundle, payload = bundle_from_params(f"weights:{cfg.name}", "v1", params)
    app, _ = make_object(
        name=f"serve:{cfg.name}",
        version="1",
        kind=ObjectKind.APPLICATION,
        refs=models.manifest_refs(cfg),
        needed=[bundle.name],
    )
    with ws.management() as tx:
        tx.publish(bundle, payload)
        tx.publish(app)
    return cfg, app.name


def _bench_generate_sync_fix(cfg, ws, app_name, *, max_new: int) -> None:
    """Satellite: the per-step host sync, before vs after, same engine."""
    from repro.serve import ServeEngine

    from .common import emit

    engine = ServeEngine.from_workspace(
        cfg, ws, app_name, cache_len=16 + max_new
    )
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (4, 16), dtype=np.int32)
    # warm both code paths (jit compile off the clock), then measure
    engine.generate(prompts, max_new, host_sync=True)
    engine.generate(prompts, max_new, host_sync=False)
    _, before = engine.generate(prompts, max_new, host_sync=True)
    out_after, after = engine.generate(prompts, max_new, host_sync=False)
    out_check, _ = engine.generate(prompts, max_new, host_sync=True)
    np.testing.assert_array_equal(out_after, out_check)
    emit(
        "serve/generate_hostsync",
        before.decode_s / max(before.tokens_out, 1),
        f"per_token;np.asarray each step;tok_s={before.tok_per_s:.0f}",
    )
    emit(
        "serve/generate_devacc",
        after.decode_s / max(after.tokens_out, 1),
        f"per_token;device accumulate;tok_s={after.tok_per_s:.0f}",
    )


def run(
    *,
    workers: int = 2,
    n_requests: int = 32,
    rate_hz: float = 200.0,
    prompt_len: int = 12,
    max_new_tokens: int = 8,
    max_batch: int = 2,
) -> None:
    from repro.serve import run_traffic

    from .common import emit, emit_value, fresh_workspace

    print("name,us_per_call,derived")
    ws = fresh_workspace()
    try:
        cfg, app_name = _publish_serve_app(ws, ARCH)
        rep = run_traffic(
            ws,
            app_name,
            arch=ARCH,
            workers=workers,
            n_requests=n_requests,
            rate_hz=rate_hz,
            prompt_len=prompt_len,
            max_new_tokens=max_new_tokens,
            max_batch=max_batch,
        )
        s = rep.summary()
        assert rep.completed == n_requests, f"lost requests: {s}"
        assert rep.failed == 0, f"worker crashes: {s}"
        assert rep.p99_s > 0 and np.isfinite(rep.p99_s), s
        tag = (
            f"workers={workers};rate_hz={rate_hz};completed={rep.completed};"
            f"stalls={rep.stalls}"
        )
        emit("serve/p50_latency", rep.p50_s, tag)
        emit("serve/p99_latency", rep.p99_s, tag)
        emit_value("serve/req_per_s", rep.req_per_s, tag)
        emit_value("serve/tok_per_s", rep.tok_per_s, tag)
        emit_value("serve/fleet_ready_s", max(rep.ready_s or [0.0]),
                   "slowest worker spin-up (epoch load + first attach)")

        _bench_generate_sync_fix(cfg, ws, app_name, max_new=max_new_tokens)
    finally:
        from .common import write_bench_json

        ws.close()
        print(f"wrote {write_bench_json(BENCH_JSON, merge=True)}")


def main() -> None:
    if "--smoke" in sys.argv:
        run(workers=2, n_requests=24, rate_hz=200.0)
        return
    run(workers=3, n_requests=96, rate_hz=400.0, max_batch=4)


if __name__ == "__main__":
    main()
