"""Training checkpoints as management-time events.

A checkpoint save is exactly a management time (§3 Integration): the trainer
calls ``begin_mgmt``, publishes the new weight/optimizer bundles with
``update_obj``, and ``end_mgmt`` re-materializes the relocation tables of
every application that references them. A restart after failure then takes
the *epoch* path: table-driven loading, no symbol resolution — the paper's
startup win applied to fault recovery.

Writes are asynchronous: tensors are snapshotted to host (device_get) on the
caller's thread, serialization + registry insertion run on a background
thread, and ``wait()`` joins before the next save (overlapping checkpoint IO
with compute).
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import numpy as np

from repro.core import Manager, Mode

from .bundle import bundle_from_params


def _flatten_opt(opt_state) -> dict[str, np.ndarray]:
    out = {}
    for mv in ("m", "v"):
        for name, arr in opt_state[mv].items():
            out[f"opt/{mv}/{name}"] = np.asarray(arr)
    out["opt/step"] = np.asarray(opt_state["step"]).reshape(1)
    return out


def _unflatten_opt(tensors: dict[str, np.ndarray]) -> dict:
    m, v = {}, {}
    for name, arr in tensors.items():
        if name.startswith("opt/m/"):
            m[name[len("opt/m/"):]] = arr
        elif name.startswith("opt/v/"):
            v[name[len("opt/v/"):]] = arr
    step = tensors["opt/step"].reshape(())
    import jax.numpy as jnp

    return {"m": m, "v": v, "step": jnp.asarray(step)}


@dataclass
class Checkpointer:
    manager: Manager
    weights_name: str
    opt_name: str
    keep_opt: bool = True
    _thread: Optional[threading.Thread] = field(default=None, repr=False)
    last_step: int = -1
    saves: int = 0
    save_seconds: float = 0.0

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, params, opt_state=None) -> None:
        """Snapshot on caller thread; publish on background thread."""
        self.wait()
        host_params = {n: np.asarray(jax.device_get(a)) for n, a in params.items()}
        host_opt = (
            _flatten_opt(jax.device_get(opt_state))
            if (opt_state is not None and self.keep_opt)
            else None
        )

        def publish():
            t0 = time.perf_counter()
            own_mgmt = self.manager.mode != Mode.MANAGEMENT
            if own_mgmt:
                self.manager.begin_mgmt()
            obj, pl = bundle_from_params(
                self.weights_name, f"step{step}", host_params,
                meta={"step": step},
            )
            self.manager.update_obj(obj, pl)
            if host_opt is not None:
                oobj, opl = bundle_from_params(
                    self.opt_name, f"step{step}", host_opt, meta={"step": step}
                )
                self.manager.update_obj(oobj, opl)
            if own_mgmt:
                self.manager.end_mgmt()  # re-materializes relocation tables
            self.last_step = step
            self.saves += 1
            self.save_seconds += time.perf_counter() - t0

        self._thread = threading.Thread(target=publish, daemon=True)
        self._thread.start()


def restore_train_state(executor, app_name: str, *, strategy: str = "stable"):
    """Epoch-path restore: table-driven load of weights (+opt if present).

    Returns (params np dict, opt tensors np dict or None, step)."""
    image = executor.load(app_name, strategy=strategy)
    params = {
        n: t for n, t in image.tensors.items() if not n.startswith("opt/")
    }
    opt_tensors = {
        n: t for n, t in image.tensors.items() if n.startswith("opt/")
    }
    step = -1
    for o in image.table.objects:
        obj = executor.registry.get(o["content_hash"])
        if "step" in obj.meta:
            step = max(step, int(obj.meta["step"]))
    opt = _unflatten_opt(opt_tensors) if opt_tensors else None
    return params, opt, step
