"""Pure-jnp oracle: materialized-softmax attention with causal/window masks."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0**30


def flash_attention_ref(
    q: jax.Array,            # (B, Sq, H, hd)
    k: jax.Array,            # (B, Sk, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * hd**-0.5
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)
