from .mesh import make_local_mesh, make_production_mesh, mesh_from_spec

__all__ = ["make_local_mesh", "make_production_mesh", "mesh_from_spec"]
